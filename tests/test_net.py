"""Socket transport tests: loopback gossip clusters, fault injection,
and the simulator ≡ socket equivalence contract.

The load-bearing properties:

* a UDP mesh with injected loss/dup/reorder still converges — δ-drops
  cost latency, never correctness;
* frames larger than the MTU are sharded and reassembled; losing one
  shard drops the *whole* frame (never a half-frame upward);
* a TCP peer dying mid-frame poisons nothing — per-connection stream
  state dies with the connection, the dialer reconnects, and
  digest-sync repairs what the torn link lost;
* bounded per-peer send queues shed oldest frames under backpressure,
  and the cluster still converges afterwards;
* one write schedule replayed through the in-process ``Simulator`` and
  through a real loopback socket cluster converges to identical stores;
* ``validate_net_args`` rejects every malformed CLI combination with a
  ValueError at parse time.
"""

import asyncio
import socket

import pytest

from repro.core import (MVRegister, NetConfig, Simulator, StoreReplica,
                        converged, make_policy, run_to_convergence)
from repro.net import (GossipNode, NetSpec, UdpTransport,
                       default_replica_factory, start_cluster,
                       start_gossip, stop_cluster, validate_net_args,
                       wait_converged)
from repro.net.node import _PeerQueue
from repro.wire import WireCodec, decode_frame, encode_frame

import random


# ---------------------------------------------------------------------------
# UDP: faulty mesh convergence, sharding, drop-whole-frame
# ---------------------------------------------------------------------------

def test_udp_cluster_converges_under_loss_dup_reorder():
    async def scenario():
        nodes = await start_cluster(3, transport="udp", tick=0.03,
                                    loss=0.15, dup=0.10, reorder=0.10,
                                    seed=3)
        try:
            for i in range(30):
                nodes[i % 3].update(f"k{i % 11}", MVRegister,
                                    "write_delta", nodes[i % 3].id, i)
                await asyncio.sleep(0.004)
            await wait_converged(nodes, timeout=30.0)
            assert sum(n.transport.injected_losses for n in nodes) > 0
            for n in nodes:
                n.check_healthy()
        finally:
            await stop_cluster(nodes)
    asyncio.run(scenario())


def test_udp_oversized_frame_is_sharded_and_reassembled():
    async def scenario():
        nodes = await start_cluster(2, transport="udp", tick=0.03,
                                    mtu=600, seed=7)
        try:
            big = "v" * 5000                  # frame well above the MTU
            nodes[0].update("blob", MVRegister, "write_delta",
                            nodes[0].id, big)
            await wait_converged(nodes, timeout=15.0)
            got = nodes[1].replica.get("blob", MVRegister).read()
            assert got == {big}
            assert nodes[0].stats.chunks_sent > 0
        finally:
            await stop_cluster(nodes)
    asyncio.run(scenario())


def test_udp_lost_shard_drops_whole_frame():
    async def scenario():
        got = []
        a, b = UdpTransport(mtu=200), UdpTransport(mtu=200)
        await a.start("127.0.0.1:0")
        await b.start("127.0.0.1:0")
        b.set_receiver(lambda src, fr: got.append(fr))
        big = encode_frame("state", b"y" * 1000)
        emit = a._emit
        calls = {"n": 0}

        def drop_second_shard(datagram, addr):
            calls["n"] += 1
            if calls["n"] != 2:
                emit(datagram, addr)

        a._emit = drop_second_shard
        await a.send_frames(b.addr, [big])
        await asyncio.sleep(0.15)
        assert got == []                      # no half-frame smuggled up
        a._emit = emit                        # and a later frame is clean
        await a.send_frames(b.addr, [big])
        for _ in range(100):
            if got:
                break
            await asyncio.sleep(0.01)
        assert len(got) == 1 and got[0].kind == "state"
        kind, payload = decode_frame(got[0])
        assert kind == "state" and bytes(payload) == b"y" * 1000
        await a.close()
        await b.close()
    asyncio.run(scenario())


def test_udp_duplicate_datagrams_are_idempotent():
    async def scenario():
        nodes = await start_cluster(2, transport="udp", tick=0.03,
                                    dup=0.5, seed=13)
        try:
            for i in range(10):
                nodes[0].update(f"d{i}", MVRegister, "write_delta",
                                nodes[0].id, i)
            await wait_converged(nodes, timeout=15.0)
            reg = nodes[1].replica.get("d3", MVRegister)
            assert reg.read() == {3}          # duplicated, not doubled
        finally:
            await stop_cluster(nodes)
    asyncio.run(scenario())


# ---------------------------------------------------------------------------
# TCP: mid-frame crash, reconnect, digest-sync repair
# ---------------------------------------------------------------------------

def test_tcp_midframe_crash_then_reconnect_converges():
    async def scenario():
        nodes = await start_cluster(2, transport="tcp", tick=0.03,
                                    start_gossip=False, seed=17)
        a, b = nodes
        try:
            # put half a frame on a real socket, then kill the link —
            # the torn bytes must never surface as a frame
            torn = encode_frame("delta", b"x" * 300)
            await a.transport.inject_raw(b.addr, bytes(torn)[:40])
            await asyncio.sleep(0.05)
            a.transport.abort_connections()
            await asyncio.sleep(0.05)
            assert b.stats.delivered == 0

            await start_gossip(nodes)         # fresh dials, fresh streams
            a.update("after", MVRegister, "write_delta", a.id, "crash")
            await wait_converged(nodes, timeout=15.0)
            assert b.replica.get("after", MVRegister).read() == {"crash"}
            for n in nodes:
                n.check_healthy()
        finally:
            await stop_cluster(nodes)
    asyncio.run(scenario())


def test_tcp_peer_restart_catches_up_via_digest_sync():
    async def scenario():
        policy = "digest-sync"
        nodes = await start_cluster(2, transport="tcp", tick=0.03,
                                    policy=policy, seed=19)
        a, b = nodes
        try:
            for i in range(12):
                a.update(f"pre{i}", MVRegister, "write_delta", a.id, i)
            await wait_converged(nodes, timeout=15.0)

            durable = b.replica.durable_snapshot()
            addr = b.addr
            await b.stop(abort=True)          # crash
            a.update("while-down", MVRegister, "write_delta", a.id, "w")
            await asyncio.sleep(0.2)

            reborn = GossipNode(b.id, addr, transport="tcp",
                                policy=policy, peers={a.id: a.addr},
                                tick=0.03)
            replica = default_replica_factory(policy)(b.id, [a.id])
            replica.recover(durable)
            reborn.adopt_replica(replica)
            await reborn.start()
            await wait_converged([a, reborn], timeout=15.0)
            got = reborn.replica.get("while-down", MVRegister).read()
            assert got == {"w"}
            # the catch-up travelled as digest traffic, not a state dump
            assert reborn.stats.recv_by_kind.get("digest-resp", 0) > 0
            assert reborn.stats.recv_by_kind.get("state", 0) == 0
            await reborn.stop()
        finally:
            await a.stop()
    asyncio.run(scenario())


# ---------------------------------------------------------------------------
# Backpressure: bounded queues shed oldest, digest-sync repairs
# ---------------------------------------------------------------------------

def test_peer_queue_drops_oldest():
    async def scenario():
        q = _PeerQueue(cap=3)
        drops = [q.put(i) for i in range(5)]
        assert sum(drops) == 2
        assert await q.get_batch() == [2, 3, 4]   # oldest shed first
    asyncio.run(scenario())


def test_backpressure_overrun_then_convergence():
    async def scenario():
        # reserve a port, leave it dark: the TCP dialer blocks in backoff
        # while the tiny queue overruns
        probe = socket.socket()
        probe.bind(("127.0.0.1", 0))
        dark_port = probe.getsockname()[1]
        probe.close()
        dark = f"127.0.0.1:{dark_port}"

        a = GossipNode("gw0", "127.0.0.1:0", transport="tcp",
                       peers={"gw1": dark}, tick=0.01, queue_cap=4)
        await a.start()
        for i in range(40):
            a.update(f"q{i}", MVRegister, "write_delta", a.id, i)
            await asyncio.sleep(0.01)
        assert a.stats.queue_drops > 0        # admission shed frames

        # now the peer comes up on that port; digest-sync repairs the shed
        b = GossipNode("gw1", dark, transport="tcp",
                       peers={"gw0": a.addr}, tick=0.01)
        await b.start()
        await wait_converged([a, b], timeout=30.0)
        assert b.replica.get("q0", MVRegister).read() == {0}
        await stop_cluster([a, b])
    asyncio.run(scenario())


# ---------------------------------------------------------------------------
# The equivalence contract: sim replay ≡ socket replay
# ---------------------------------------------------------------------------

def _schedule(n_writes=36, n_keys=9, seed=29):
    rng = random.Random(seed)
    return [(rng.randrange(3), f"k{rng.randrange(n_keys)}", f"v{i}")
            for i in range(n_writes)]


def test_sim_socket_equivalence():
    from repro.obs import Tracer, report, semantic_trace

    schedule = _schedule()
    ids = ["gw0", "gw1", "gw2"]

    # --- simulator replay (traced, deterministic sim clock) -----------------
    sim = Simulator(NetConfig(seed=0))
    sim_tracers = {i: Tracer(node=i, clock=lambda: sim.time) for i in ids}
    sim_nodes = []
    for i in ids:
        r = default_replica_factory()(i, [j for j in ids if j != i])
        r.tracer = sim_tracers[i]
        sim_nodes.append(sim.add_node(r))
    for who, key, val in schedule:
        sim_nodes[who].update(key, MVRegister, "write_delta",
                              ids[who], val)
    run_to_convergence(sim, sim_nodes, interval=1.0, max_time=60_000)
    assert converged(sim_nodes)

    # --- socket replay (same ids, same codec, same policy, traced) ----------
    socket_tracers = {}

    def tracer_factory(node_id):
        socket_tracers[node_id] = Tracer(node=node_id)
        return socket_tracers[node_id]

    async def scenario():
        nodes = await start_cluster(3, transport="udp", tick=0.03,
                                    start_gossip=False, seed=31,
                                    tracer_factory=tracer_factory)
        try:
            for who, key, val in schedule:
                nodes[who].update(key, MVRegister, "write_delta",
                                  ids[who], val)
            await start_gossip(nodes)
            await wait_converged(nodes, timeout=30.0)
            return [n.X for n in nodes]
        finally:
            await stop_cluster(nodes)

    socket_states = asyncio.run(scenario())
    # identical converged stores: same dots, same read sets, lattice-equal
    for xs in socket_states:
        assert xs == sim_nodes[0].X
    for key in {k for _, k, _ in schedule}:
        assert (socket_states[0].get(key).read()
                == sim_nodes[0].X.get(key).read())

    # the trace-equivalence contract: both replays' event streams tell
    # the same timing-free story — per key, the same writers issuing the
    # same write counts, converging to the same holder set — and neither
    # trace contains a consistency anomaly
    sim_semantic = semantic_trace(list(sim_tracers.values()))
    sock_semantic = semantic_trace(list(socket_tracers.values()))
    assert sim_semantic == sock_semantic
    assert set(sim_semantic) == {k for _, k, _ in schedule}
    assert all(rec["joined"] == ids for rec in sim_semantic.values())
    for tracers in (sim_tracers, socket_tracers):
        rep = report(list(tracers.values()), expect_converged=ids)
        assert rep["anomaly_list"] == []
        assert rep["unconverged_keys"] == {}


# ---------------------------------------------------------------------------
# CLI validation (serve.py --listen/--peers)
# ---------------------------------------------------------------------------

def test_validate_net_args_happy_path():
    spec = validate_net_args("gw0@127.0.0.1:7000",
                             "gw1@127.0.0.1:7001,gw2@127.0.0.1:7002")
    assert isinstance(spec, NetSpec)
    assert spec.node_id == "gw0" and spec.listen == "127.0.0.1:7000"
    assert spec.peers == {"gw1": "127.0.0.1:7001",
                          "gw2": "127.0.0.1:7002"}
    assert spec.cluster_ids == ["gw0", "gw1", "gw2"]


def test_validate_net_args_bare_addresses_name_themselves():
    spec = validate_net_args("127.0.0.1:7000", "127.0.0.1:7001")
    assert spec.node_id == "127.0.0.1:7000"
    assert spec.peers == {"127.0.0.1:7001": "127.0.0.1:7001"}


@pytest.mark.parametrize("listen,peers,kwargs,match", [
    ("127.0.0.1:7000", None, {}, "BOTH"),
    (None, "127.0.0.1:7001", {}, "BOTH"),
    ("a@127.0.0.1:7000", "b@127.0.0.1:7001", {"wire": False}, "no-wire"),
    ("a@127.0.0.1:7000", "b@127.0.0.1:7001",
     {"transport": "carrier-pigeon"}, "transport"),
    ("a@127.0.0.1:7000", "b@127.0.0.1:7001",
     {"transport": "tcp", "udp_loss": 0.1}, "UDP-only"),
    ("a@127.0.0.1:7000", "b@127.0.0.1:7001", {"udp_loss": 1.5}, "0, 1"),
    ("a@127.0.0.1:7000", "b@127.0.0.1:7001",
     {"session_ttl": -2.0}, "positive"),
    ("a@127.0.0.1:7000@z0", "b@127.0.0.1:7001", {}, "every member"),
    ("a@127.0.0.1:7000", "b@127.0.0.1:7001@z1,c@127.0.0.1:7002", {},
     "every member"),
    ("@127.0.0.1:7000@z0", "b@127.0.0.1:7001@z1", {}, "ID@HOST:PORT@ZONE"),
    ("a@127.0.0.1:7000@z0@extra", "b@127.0.0.1:7001@z1", {},
     r"\[ID@\]HOST:PORT\[@ZONE\]"),
    ("a@127.0.0.1:7000", "a@127.0.0.1:7001", {}, "self-gossip"),
    ("a@127.0.0.1:7000", "127.0.0.1:7000", {}, "self-gossip"),
    ("a@127.0.0.1:7000", "b@127.0.0.1:7001,b@127.0.0.1:7002", {},
     "duplicate"),
    ("a@127.0.0.1:7000", ",", {}, "no cluster members"),
    ("a@127.0.0.1:7000", "b@127.0.0.1:0", {}, "port 0"),
    ("a@127.0.0.1:notaport", "b@127.0.0.1:7001", {}, "port"),
])
def test_validate_net_args_rejections(listen, peers, kwargs, match):
    with pytest.raises(ValueError, match=match):
        validate_net_args(listen, peers, **kwargs)


def test_gossip_node_refuses_objects_on_the_wire():
    async def scenario():
        nodes = await start_cluster(2, transport="udp", tick=0.03,
                                    seed=37)
        try:
            with pytest.raises(TypeError, match="WireCodec"):
                nodes[0].send("gw0", "gw1", {"not": "bytes"})
        finally:
            await stop_cluster(nodes)
    asyncio.run(scenario())


def test_gossip_node_refuses_wireless_replica():
    async def scenario():
        def wireless(node_id, neighbors):
            return StoreReplica(node_id, list(neighbors), causal=True,
                                policy=make_policy("bp+rr"),
                                rng=random.Random(1), wire=None)
        nodes = await start_cluster(2, transport="udp",
                                    replica_factory=wireless,
                                    start_gossip=False, seed=41)
        with pytest.raises(ValueError, match="wire"):
            await start_gossip(nodes)
        await stop_cluster(nodes)
    asyncio.run(scenario())


# ---------------------------------------------------------------------------
# Zoned clusters: CLI zones, link-class accounting, hierarchical gossip,
# socket-mode key lifecycle (the reaper quorum over real UDP)
# ---------------------------------------------------------------------------

def test_validate_net_args_zones_and_ttl():
    spec = validate_net_args(
        "gw0@127.0.0.1:7000@eu/a",
        "gw1@127.0.0.1:7001@eu/b,gw2@127.0.0.1:7002@us/a",
        session_ttl=4.0)
    assert spec.zones == {"gw0": "eu/a", "gw1": "eu/b", "gw2": "us/a"}
    assert spec.session_ttl == 4.0
    topo = spec.topology
    assert topo.link_class("gw0", "gw1") == "inter"   # eu/a ↔ eu/b
    assert topo.link_class("gw0", "gw2") == "wan"     # eu ↔ us
    # flat spec: no zones, no topology, ttl defaults off
    flat = validate_net_args("gw0@127.0.0.1:7000", "gw1@127.0.0.1:7001")
    assert flat.zones == {} and flat.topology is None
    assert flat.session_ttl is None


def test_sim_socket_equivalence_zoned():
    """The PR-8 equivalence contract extended to a zoned cluster: one
    write schedule replayed through a zoned Simulator and through a
    zoned loopback socket cluster — both under hierarchical gossip —
    converges to identical stores."""
    from repro.topology import Topology
    from repro.core import hierarchical_policy

    schedule = _schedule()
    ids = ["gw0", "gw1", "gw2"]
    topo = Topology.zoned(ids, 3)          # one member per zone
    policy = lambda: hierarchical_policy(topo, base="bp+rr")

    sim = Simulator(NetConfig(seed=0), topology=topo)
    sim_nodes = [sim.add_node(default_replica_factory(policy=policy)(
        i, [j for j in ids if j != i])) for i in ids]
    for who, key, val in schedule:
        sim_nodes[who].update(key, MVRegister, "write_delta",
                              ids[who], val)
    run_to_convergence(sim, sim_nodes, interval=1.0, max_time=60_000)
    assert converged(sim_nodes)
    assert sim.stats.cross_zone_bytes() > 0   # zones actually traded

    async def scenario():
        nodes = await start_cluster(
            3, transport="udp", tick=0.03, start_gossip=False, seed=31,
            topology=topo,
            replica_factory=default_replica_factory(policy=policy))
        try:
            for who, key, val in schedule:
                nodes[who].update(key, MVRegister, "write_delta",
                                  ids[who], val)
            await start_gossip(nodes)
            await wait_converged(nodes, timeout=30.0)
            return [n.X for n in nodes], [n.stats for n in nodes]
        finally:
            await stop_cluster(nodes)

    socket_states, stats = asyncio.run(scenario())
    for xs in socket_states:
        assert xs == sim_nodes[0].X
    assert sum(s.cross_zone_bytes() for s in stats) > 0


def test_socket_zoned_cluster_only_relays_cross_zones():
    """On a 3-zone × 2 socket cluster under hierarchical gossip, every
    frame is classed, and cross-zone bytes originate from the elected
    relays only — non-relay members push intra-zone."""
    from repro.topology import Topology
    from repro.core import hierarchical_policy

    ids = [f"gw{k}" for k in range(6)]
    topo = Topology.zoned(ids, 3)
    relays = {topo.relay(z, ids) for z in topo.zone_names(ids)}
    assert len(relays) == 3

    async def scenario():
        nodes = await start_cluster(
            6, transport="udp", tick=0.03, seed=47, topology=topo,
            start_gossip=False,
            replica_factory=default_replica_factory(
                policy=lambda: hierarchical_policy(topo)))
        try:
            for i, n in enumerate(nodes):
                n.update(f"k{i}", MVRegister, "write_delta", n.id, i)
            await start_gossip(nodes)
            await wait_converged(nodes, timeout=30.0)
            # a couple of extra ticks so in-flight digests are counted
            await asyncio.sleep(0.2)
            return {n.id: n.stats for n in nodes}
        finally:
            await stop_cluster(nodes)

    stats = asyncio.run(scenario())
    for nid, s in stats.items():
        assert s.bytes_by_class, f"{nid}: no frames were link-classed"
        if nid in relays:
            assert s.cross_zone_bytes() > 0, f"relay {nid} never crossed"
        else:
            assert s.cross_zone_bytes() == 0, (
                f"non-relay {nid} sent cross-zone bytes: "
                f"{s.bytes_by_class}")


def test_socket_session_ttl_reaper_quorum_over_udp():
    """--session-ttl in socket mode: full-replication KeyOwnership +
    ReaperProtocol threaded through GossipNode — expired session keys
    are tombstoned on every member via reap/reap-ack frames over real
    UDP, exactly the sim-mode lifecycle story."""
    from repro.lifecycle import ReaperProtocol
    from repro.sync import KeyOwnership
    from repro.core.propagation import stable_seed

    ids = [f"gw{k}" for k in range(3)]
    ownership = KeyOwnership(ids, replication=len(ids))

    def factory(node_id, neighbors):
        r = StoreReplica(node_id, list(neighbors), causal=True,
                         policy=make_policy("bp+rr+digest-sync:4"),
                         rng=random.Random(stable_seed(node_id)),
                         wire=WireCodec(), ownership=ownership, ttl=0.8)
        ReaperProtocol(r, ownership, grace=0.2, retry=0.3)
        return r

    async def scenario():
        import time as _time
        nodes = await start_cluster(3, replica_factory=factory, tick=0.05,
                                    seed=53)
        try:
            for i, n in enumerate(nodes):
                n.update(f"sess{i}", MVRegister, "write_delta", n.id,
                         "done")
            await wait_converged(nodes, timeout=20.0)

            def reaped():
                return all(len(n.X.tombstoned_keys()) == 3
                           and not n.X.keys() for n in nodes)
            t0 = _time.monotonic()
            while not reaped() and _time.monotonic() - t0 < 20.0:
                for n in nodes:
                    n.check_healthy()
                await asyncio.sleep(0.1)
            assert reaped(), (
                "expired keys not tombstoned everywhere: "
                + "; ".join(f"{n.id}:{sorted(n.X.keys())}" for n in nodes))
            # the quorum ran over the wire: reap frames were exchanged
            assert sum(n.stats.by_kind.get("reap", 0) for n in nodes) > 0
            assert sum(n.stats.by_kind.get("reap-ack", 0)
                       for n in nodes) > 0
        finally:
            await stop_cluster(nodes)

    asyncio.run(scenario())
