"""Observability subsystem tests: trace bus, metrics registry, derived
probes, scrape surface, and the trace analyzer.

The load-bearing properties:

* the tracer is a bounded, sampled, optionally file-backed ring whose
  JSONL sink round-trips through ``load_trace``; unknown event kinds
  fail loudly at the emit site;
* a traced simulator run produces a clean trace: writes, ships, joins
  and acks that the analyzer can roll up with zero anomalies, a
  redundancy ratio ≥ 1, and per-key convergence lag;
* the registry's families render valid Prometheus text and a JSON
  snapshot; absorbers mirror live stats objects without the call sites
  changing; collectors run at scrape time;
* ``ReplicaProbes`` / ``AckLagProbe`` read engine health straight off a
  live replica (buffer depth, GC horizon age, write→acked latency);
* kernel launches are observable by name through the process-wide hook;
  ``KernelCounters`` is snapshot-and-diff only (no global reset);
* the scrape sidecar serves both views over real sockets;
* the synthetic-trace anomaly detectors fire on exactly the corrupted
  streams they claim to catch;
* ``sync.metrics`` is a live re-export shim over ``obs.registry``.
"""

import asyncio
import json
import random

import pytest

from repro.core import (AWORSet, MVRegister, NetConfig, Replica, Simulator,
                        StoreReplica, converged, make_policy,
                        run_to_convergence)
from repro.obs import (AckLagProbe, EVENT_KINDS, MetricsServer, Registry,
                       ReplicaProbes, Tracer, anomalies, convergence,
                       load_trace, marker_lag_histogram, merge_events,
                       parse_prometheus, redundancy, report, scrape,
                       scrape_json, semantic_trace, trace_kernel_launches)


# ---------------------------------------------------------------------------
# Tracer mechanics
# ---------------------------------------------------------------------------

def test_tracer_ring_sink_and_clock(tmp_path):
    t = [0.0]
    path = str(tmp_path / "trace.jsonl")
    with Tracer(node="a", clock=lambda: t[0], capacity=4,
                sink=path) as tr:
        for i in range(6):
            t[0] = float(i)
            tr.emit("write", keys=[f"k{i}"], tag=i)
    evs = tr.events()
    assert len(evs) == 4                      # ring kept the newest 4
    assert [e["t"] for e in evs] == [2.0, 3.0, 4.0, 5.0]
    assert [e["seq"] for e in evs] == [2, 3, 4, 5]
    assert all(e["node"] == "a" for e in evs)
    disk = load_trace(path)                   # the sink kept all 6
    assert len(disk) == 6 and disk[0]["keys"] == ["k0"]


def test_tracer_rejects_unknown_kind():
    tr = Tracer(node="a")
    with pytest.raises(ValueError, match="unknown trace event kind"):
        tr.emit("delta_shiip", dst="b")
    assert "delta_ship" in EVENT_KINDS


def test_tracer_sampling_is_seeded():
    def run():
        tr = Tracer(node="a", sample=0.5, seed=7)
        for i in range(200):
            tr.emit("write", keys=["k"], tag=i)
        return [e["tag"] for e in tr.events()], tr.dropped
    kept1, dropped1 = run()
    kept2, dropped2 = run()
    assert kept1 == kept2 and dropped1 == dropped2    # reproducible
    assert 0 < len(kept1) < 200 and dropped1 == 200 - len(kept1)


def test_merge_events_orders_by_time_then_seq():
    a, b = Tracer(node="a", clock=lambda: 1.0), Tracer(node="b",
                                                       clock=lambda: 0.5)
    a.emit("write", keys=["x"], tag=0)
    a.emit("ack", src="b", tag=1)
    b.emit("write", keys=["y"], tag=0)
    merged = merge_events(a, b)
    assert [e["node"] for e in merged] == ["b", "a", "a"]
    assert [e["seq"] for e in merged if e["node"] == "a"] == [0, 1]


# ---------------------------------------------------------------------------
# Traced engine: simulator runs feed the analyzer
# ---------------------------------------------------------------------------

def _traced_sim(policy="bp+rr", n=3, writes=6, loss=0.2):
    ids = [f"n{k}" for k in range(n)]
    sim = Simulator(NetConfig(loss=loss, seed=5))
    tracers = {i: Tracer(node=i, clock=lambda: sim.time) for i in ids}
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=make_policy(policy), rng=random.Random(11),
        tracer=tracers[i])) for i in ids]
    for w in range(writes):
        nodes[w % n].update(f"k{w}", MVRegister, "write_delta",
                            ids[w % n], f"v{w}")
        sim.run_for(0.5)
    run_to_convergence(sim, nodes, interval=1.0)
    assert converged(nodes)
    return ids, nodes, list(tracers.values())


def test_traced_sim_run_is_clean_and_converged():
    ids, nodes, tracers = _traced_sim()
    rep = report(tracers, expect_converged=ids)
    assert rep["anomaly_list"] == []
    assert rep["unconverged_keys"] == {}
    assert rep["keys"] == 6
    assert rep["redundancy"]["ratio"] >= 1.0
    assert rep["redundancy"]["shipped_bytes"] > 0
    assert rep["mean_rounds"] >= 0.0 and rep["max_lag_s"] > 0.0
    counts = {}
    for tr in tracers:
        for k, v in tr.counts().items():
            counts[k] = counts.get(k, 0) + v
    assert counts["write"] == 6
    assert counts["delta_ship"] > 0 and counts["delta_join"] > 0
    assert counts["ack"] > 0                  # bp needs the ack stream


def test_traced_sim_gc_horizon_events():
    _, nodes, tracers = _traced_sim(writes=8)
    for n in nodes:
        n.gc_deltas()
    gc = [e for tr in tracers for e in tr.events()
          if e["kind"] == "gc_horizon_advance"]
    assert gc, "converged buffers never reported a GC advance"
    assert all(e["dropped"] > 0 and e["horizon"] > 0 for e in gc)
    # the advance events account exactly for what left the buffers
    by_node = {e["node"]: e for tr in tracers for e in tr.events()
               if e["kind"] == "gc_horizon_advance"}
    for n in nodes:
        if n.id in by_node:
            assert len(n.entries) <= by_node[n.id]["depth"]


def test_traced_digest_sync_emits_pull_round_events():
    _, _, tracers = _traced_sim(policy="bp+rr+digest-sync:2", writes=6)
    counts = {}
    for tr in tracers:
        for k, v in tr.counts().items():
            counts[k] = counts.get(k, 0) + v
    assert counts.get("digest_req", 0) > 0
    rep = report(tracers)
    assert rep["anomaly_list"] == []


def test_traced_reaper_lifecycle_events():
    from repro.lifecycle import ReaperProtocol
    from repro.sync import KeyOwnership

    ids = ["n0", "n1", "n2"]
    ownership = KeyOwnership(ids, replication=3)
    sim = Simulator(NetConfig(seed=9))
    tracers = {i: Tracer(node=i, clock=lambda: sim.time) for i in ids}
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=make_policy("bp+rr"), rng=random.Random(13),
        ownership=ownership, ttl=2.0, tracer=tracers[i])) for i in ids]
    for n in nodes:
        ReaperProtocol(n, ownership, grace=0.5, retry=1.0)
        sim.every(1.0, n.on_periodic)
    nodes[0].update("sess", MVRegister, "write_delta", "n0", "done")
    sim.run_for(60.0)
    assert all("sess" in n.X.tombstoned_keys() for n in nodes)
    evs = merge_events(*tracers.values())
    kinds = {e["kind"] for e in evs}
    assert {"reap_propose", "reap_ack", "reap_commit"} <= kinds
    commit = next(e for e in evs if e["kind"] == "reap_commit")
    assert commit["key"] == "sess" and commit["acks"] == 2


# ---------------------------------------------------------------------------
# Registry: families, rendering, collectors, absorbers
# ---------------------------------------------------------------------------

def test_registry_families_render_and_snapshot():
    reg = Registry()
    c = reg.counter("frames_total", "frames", ("node",))
    c.labels("a").inc(3)
    c.labels(node="b").inc()
    g = reg.gauge("depth", "buffered entries")
    g.set(4.5)
    h = reg.histogram("lag_seconds", "lag", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    text = reg.render_prometheus()
    parsed = parse_prometheus(text)
    assert parsed["frames_total"] == {'node="a"': 3.0, 'node="b"': 1.0}
    assert parsed["depth"][""] == 4.5
    assert parsed["lag_seconds_bucket"]['le="1"'] == 3.0   # cumulative
    assert parsed["lag_seconds_bucket"]['le="+Inf"'] == 4.0
    assert parsed["lag_seconds_count"][""] == 4.0
    assert "# TYPE lag_seconds histogram" in text
    snap = reg.snapshot()
    assert snap["frames_total"] == {"a": 3.0, "b": 1.0}
    assert snap["depth"] == 4.5
    assert snap["lag_seconds"]["count"] == 4
    assert h.approx_quantile(0.5) == 1.0
    # the JSON view survives non-finite floats
    reg.gauge("weird").set(float("inf"))
    assert json.loads(reg.render_json())["weird"] == "inf"


def test_registry_is_idempotent_and_rejects_redeclaration():
    reg = Registry()
    a = reg.counter("x_total", "x", ("node",))
    assert reg.counter("x_total", "x", ("node",)) is a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("x_total", "x", ("node", "peer"))
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad name")
    with pytest.raises(ValueError, match="reserved"):
        reg.histogram("h", "h", ("le",))
    with pytest.raises(ValueError, match="counters only go up"):
        a.labels("a").inc(-1)


def test_registry_gauge_set_function_and_collectors():
    reg = Registry()
    depth = [7]
    reg.gauge("live_depth").set_function(lambda: depth[0])
    seen = []
    reg.add_collector(lambda: seen.append(True))
    snap = reg.snapshot()
    assert snap["live_depth"] == 7.0 and seen == [True]
    depth[0] = 9
    assert reg.snapshot()["live_depth"] == 9.0


def test_absorb_link_stats_publishes_totals_and_finite_rates():
    from repro.net.stats import LinkStats

    stats = LinkStats()
    stats.record("delta", 100)
    stats.record("digest", 40)
    stats.record_recv("delta", 80)
    stats.queue_drops += 2
    clock = [100.0]
    reg = Registry()
    reg.absorb_link_stats(stats, node="gw0", clock=lambda: clock[0])
    snap = reg.snapshot()
    assert snap["repro_net_bytes_sent_total"]["gw0"] == 140.0
    assert snap["repro_net_bytes_by_kind_total"]["gw0,delta"] == 100.0
    assert snap["repro_net_bytes_recv_total"]["gw0"] == 80.0
    assert snap["repro_net_queue_drops_total"]["gw0"] == 2.0
    # rate gauges exist and are finite from the FIRST scrape on
    assert snap["repro_net_bytes_sent_per_second"]["gw0"] == 0.0
    stats.record("delta", 50)
    clock[0] += 10.0
    snap = reg.snapshot()
    assert snap["repro_net_bytes_sent_per_second"]["gw0"] == 5.0
    # the live stats object stayed the accumulator: no call-site churn
    assert stats.bytes_sent == 190


def test_absorb_crdt_metrics_surfaces_replicated_aggregates():
    from repro.sync import Metrics

    m = Metrics("r1")
    m.observe("lat", 2.0)
    m.observe("lat", 4.0)
    reg = Registry()
    reg.absorb_crdt_metrics(m, node="r1")
    snap = reg.snapshot()
    assert snap["repro_crdt_metric_count"]["r1,lat"] == 2.0
    assert snap["repro_crdt_metric_sum"]["r1,lat"] == 6.0


def test_sync_metrics_is_a_live_shim():
    import repro.sync.metrics as legacy
    from repro.obs import registry as home

    assert legacy.Metrics is home.Metrics
    assert legacy.MetricsState is home.MetricsState
    assert legacy.MetricRecord is home.MetricRecord


# ---------------------------------------------------------------------------
# Engine probes
# ---------------------------------------------------------------------------

def test_replica_probes_read_live_engine_state():
    ids, nodes, _ = _traced_sim(writes=4)
    reg = Registry()
    for n in nodes:
        ReplicaProbes(reg, n)
    snap = reg.snapshot()
    assert set(snap["repro_replica_delta_buffer_depth"]) == set(ids)
    # the gauges mirror the live engine maps exactly
    by_id = {n.id: n for n in nodes}
    for i in ids:
        r = by_id[i]
        assert snap["repro_replica_delta_buffer_depth"][i] == len(r.entries)
        assert snap["repro_replica_counter"][i] == r.c >= 1
        assert snap["repro_replica_rounds_total"][i] == r.rounds > 0
        age = snap["repro_replica_gc_horizon_age"][i]
        assert age == r.c - snap["repro_replica_gc_horizon"][i] >= 0
    assert all(v >= 0.0
               for v in snap["repro_replica_unacked_entries"].values())
    # a fresh write is immediately visible at the next scrape
    nodes[0].update("late", MVRegister, "write_delta", ids[0], 1)
    assert (reg.snapshot()["repro_replica_delta_buffer_depth"][ids[0]]
            == len(nodes[0].entries))


def test_ack_lag_probe_resolves_after_acks():
    ids = ["a", "b", "c"]
    sim = Simulator(NetConfig(seed=3))
    nodes = [sim.add_node(Replica(i, AWORSet.bottom(),
                                  [j for j in ids if j != i], causal=True,
                                  policy=make_policy("bp+rr"),
                                  rng=random.Random(1)))
             for i in ids]
    reg = Registry()
    probe = AckLagProbe(reg, nodes[0], clock=lambda: sim.time)
    for k in range(3):
        nodes[0].operation(lambda X, k=k: X.add_delta("a", f"x{k}"))
        probe.note_write()
    assert probe.poll() == 0                  # nothing acked yet
    run_to_convergence(sim, nodes, interval=1.0)
    assert probe.poll() == 3
    snap = reg.snapshot()
    assert snap["repro_ack_lag_seconds"]["a"]["count"] == 3
    assert snap["repro_ack_pending_writes"]["a"] == 0.0


def test_marker_lag_histogram_shared_family():
    reg = Registry()
    child = marker_lag_histogram(reg, node="gw0")
    child.observe(0.2)
    marker_lag_histogram(reg, node="gw0").observe(0.3)   # same child
    snap = reg.snapshot()
    assert snap["repro_marker_lag_seconds"]["gw0"]["count"] == 2


# ---------------------------------------------------------------------------
# Kernel launch observability
# ---------------------------------------------------------------------------

def test_kernel_counters_snapshot_and_diff_only():
    from repro.kernels import ops

    assert not hasattr(ops.counters, "reset")
    snap = ops.counters.snapshot()
    ops.record_launch("probe_op")
    diff = ops.counters.since(snap)
    assert diff["launches"] == 1 and diff["h2d_bytes"] == 0


def test_kernel_launch_hook_names_ops(monkeypatch):
    import numpy as np
    from repro.kernels import ops

    tr = Tracer(node="kern")
    uninstall = trace_kernel_launches(tr)
    try:
        x = np.zeros((2, 256), np.float32)
        ops.chunk_digest_auto(x)
    finally:
        uninstall()
    evs = [e for e in tr.events() if e["kind"] == "kernel_launch"]
    assert evs and evs[-1]["op"] == "chunk_digest"
    assert evs[-1]["h2d_bytes"] == x.nbytes
    ops.record_launch("after_uninstall")      # hook removed: no emit
    assert len(tr.events()) == len(evs)


# ---------------------------------------------------------------------------
# Scrape surface
# ---------------------------------------------------------------------------

def test_metrics_server_serves_both_views_over_sockets():
    reg = Registry()
    reg.counter("hits_total", "hits").inc(5)
    reg.gauge("depth", "d").set(2.0)

    async def scenario():
        server = MetricsServer(reg)
        addr = await server.start()
        try:
            text = await asyncio.to_thread(scrape, addr)
            js = await asyncio.to_thread(scrape_json, addr)
            with pytest.raises(RuntimeError, match="404"):
                await asyncio.to_thread(scrape, addr, "/nope")
            return text, js
        finally:
            await server.stop()

    text, js = asyncio.run(scenario())
    parsed = parse_prometheus(text)
    assert parsed["hits_total"][""] == 5.0
    assert js == {"hits_total": 5.0, "depth": 2.0}


# ---------------------------------------------------------------------------
# Analyzer on synthetic traces: each detector fires on its corruption
# ---------------------------------------------------------------------------

def _ev(kind, node, t, **f):
    return {"t": t, "seq": f.pop("seq", 0), "node": node, "kind": kind,
            **f}


def test_redundancy_counts_wasted_ships():
    trace = [
        _ev("delta_ship", "a", 0.0, dst="b", bytes=100, keys=["k"],
            full=False, tag=1),
        _ev("delta_join", "b", 0.1, src="a", via="delta", bytes=100,
            keys=["k"], joined=1),
        _ev("delta_ship", "a", 0.2, dst="b", bytes=100, keys=["k"],
            full=False, tag=1),
        _ev("delta_join", "b", 0.3, src="a", via="delta", bytes=100,
            keys=[], joined=0),
    ]
    red = redundancy(trace)
    assert red["ratio"] == 2.0
    assert red["redundant_joins"] == 1 and red["joins"] == 2


def test_convergence_measures_lag_and_rounds():
    trace = [
        _ev("write", "a", 1.0, keys=["k"], tag=0, round=3),
        _ev("delta_ship", "a", 1.5, dst="b", bytes=10, keys=["k"],
            full=False, tag=1, round=4),
        _ev("delta_join", "b", 2.0, src="a", via="delta", bytes=10,
            keys=["k"], joined=1, round=1),
        _ev("delta_ship", "a", 2.5, dst="c", bytes=10, keys=["k"],
            full=False, tag=1, round=5),
        _ev("delta_join", "c", 4.0, src="a", via="delta", bytes=10,
            keys=["k"], joined=1, round=1),
    ]
    conv = convergence(trace)
    assert conv["k"]["lag_s"] == 3.0          # last write → last join
    assert conv["k"]["rounds"] == 2           # two distinct ship rounds
    assert conv["k"]["nodes"] == ["a", "b", "c"]
    assert conv["k"]["writers"] == ["a"]


def test_anomaly_ack_without_and_above_ship():
    trace = [
        _ev("ack", "a", 0.5, src="b", tag=3, stale=False),
        _ev("delta_ship", "a", 1.0, dst="c", bytes=10, keys=["k"],
            full=False, tag=2),
        _ev("ack", "a", 1.5, src="c", tag=9, stale=False),
    ]
    kinds = [a["kind"] for a in anomalies(trace)]
    assert kinds.count("ack_without_ship") == 1
    assert kinds.count("ack_above_ship") == 1


def test_anomaly_ship_before_have_and_without_join():
    trace = [
        _ev("write", "a", 0.0, keys=["k"], tag=0),
        _ev("delta_ship", "a", 0.1, dst="b", bytes=10, keys=["k"],
            full=False, tag=1),
        _ev("delta_ship", "b", 0.2, dst="a", bytes=10, keys=["k"],
            full=False, tag=1),              # b never wrote/joined k
    ]
    kinds = [a["kind"] for a in anomalies(trace)]
    assert "ship_before_have" in kinds
    assert "ship_without_join" in kinds       # k never joined anywhere
    # a full-state ship is exempt (bootstrap legitimately ships unknowns)
    trace[2] = _ev("delta_ship", "b", 0.2, dst="a", bytes=10,
                   keys=["k"], full=True)
    assert "ship_before_have" not in [a["kind"] for a in anomalies(trace)]


def test_anomaly_checks_disabled_on_truncation():
    trace = [
        _ev("write", "a", 0.0, keys=["k"], tag=0),
        _ev("delta_ship", "b", 0.2, dst="a", bytes=10, keys=["k"],
            full=False, tag=1, keys_truncated=True),
    ]
    kinds = [a["kind"] for a in anomalies(trace)]
    assert kinds == ["keys_truncated"]        # no false positives


def test_semantic_trace_is_timing_free():
    fast = [
        _ev("write", "a", 0.0, keys=["k"], tag=0),
        _ev("delta_join", "b", 0.1, src="a", via="delta", bytes=5,
            keys=["k"], joined=1),
    ]
    slow = [                                   # same story, other timing
        _ev("write", "a", 7.0, keys=["k"], tag=0),
        _ev("delta_join", "b", 93.0, src="c", via="digest-resp",
            bytes=999, keys=["k"], joined=1),
        _ev("delta_join", "b", 94.0, src="a", via="delta", bytes=5,
            keys=[], joined=0),                # redundant: not semantic
    ]
    assert semantic_trace(fast) == semantic_trace(slow)
    assert semantic_trace(fast) == {
        "k": {"writes": {"a": 1}, "joined": ["a", "b"]}}


# ---------------------------------------------------------------------------
# The full loop on real sockets: traced cluster, probes, scrape, analyze
# ---------------------------------------------------------------------------

def test_traced_socket_cluster_scrape_and_analyze():
    from repro.net import start_cluster, stop_cluster, wait_converged

    tracers = {}

    def tf(node_id):
        tracers[node_id] = Tracer(node=node_id)
        return tracers[node_id]

    async def scenario():
        nodes = await start_cluster(3, transport="udp", tick=0.03,
                                    seed=61, tracer_factory=tf)
        try:
            addrs = []
            for n in nodes:
                n.export_metrics()
                addrs.append(await n.serve_metrics())
            for k, n in enumerate(nodes):
                n.update(f"s{k}", MVRegister, "write_delta", n.id, "done")
            await wait_converged(nodes, timeout=30.0)
            await asyncio.sleep(0.2)          # let trailing acks land
            texts = [await asyncio.to_thread(scrape, a) for a in addrs]
            return [n.id for n in nodes], texts
        finally:
            await stop_cluster(nodes)

    ids, texts = asyncio.run(scenario())
    for nid, text in zip(ids, texts):
        parsed = parse_prometheus(text)
        assert parsed["repro_net_frames_sent_total"][f'node="{nid}"'] > 0
        assert f'node="{nid}"' in parsed["repro_net_bytes_sent_per_second"]
        assert f'node="{nid}"' in parsed["repro_replica_delta_buffer_depth"]
        assert parsed["repro_ack_lag_seconds_count"][f'node="{nid}"'] >= 1
    rep = report(list(tracers.values()), expect_converged=ids)
    assert rep["anomaly_list"] == []
    assert rep["unconverged_keys"] == {}
    assert rep["redundancy"]["ratio"] >= 1.0
