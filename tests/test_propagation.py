"""Unified propagation runtime: policy semantics, wrapper compatibility,
and the BP/RR byte-reduction invariants (deterministic; the hypothesis
property sweep lives in test_propagation_properties.py)."""

import random

import numpy as np
import pytest

from repro.core import (AWORSet, AvoidBackPropagation, BasicNode, CausalNode,
                        Compose, DigestBudget, GCounter, NetConfig,
                        POLICY_SPECS, RemoveRedundant, Replica, ShipAll,
                        ShipStateEveryK, Simulator, converged, make_policy,
                        run_to_convergence, stable_seed, structural_size)


class _CaptureSim:
    """Duck-typed stand-in for Simulator: records sends, no delivery."""

    def __init__(self):
        self.sent = []

    def send(self, src, dst, msg):
        self.sent.append((src, dst, msg))


def _deltas_to(cap, dst):
    return [m for s, d, m in cap.sent if d == dst and m[0] == "delta"]


# ---------------------------------------------------------------------------
# make_policy / composition
# ---------------------------------------------------------------------------

def test_make_policy_parses_atoms_and_compositions():
    assert isinstance(make_policy("all"), ShipAll)
    assert isinstance(make_policy("bp"), AvoidBackPropagation)
    assert isinstance(make_policy("rr"), RemoveRedundant)
    assert make_policy("every:7").k == 7
    assert make_policy("digest:4096").budget_bytes == 4096
    combo = make_policy("bp+rr")
    assert isinstance(combo, Compose)
    assert combo.requires_known_state
    with pytest.raises(ValueError):
        make_policy("nope")


def test_stable_seed_is_process_independent():
    # crc32, not salted hash(): the exact value is part of the contract
    import zlib
    assert stable_seed("pod7") == zlib.crc32(b"pod7") & 0xFFFF
    assert stable_seed("pod7") == stable_seed("pod7")
    assert stable_seed("pod7") != stable_seed("pod8")


# ---------------------------------------------------------------------------
# BP: never echo a delta to its origin
# ---------------------------------------------------------------------------

def test_bp_filters_origin_but_still_ships_bottom_for_acks():
    cap = _CaptureSim()
    r = CausalNode("a", GCounter.bottom(), ["b", "c"],
                   policy=AvoidBackPropagation())
    r.attach(cap)
    # a delta arrives from b and is buffered with origin=b
    r.on_receive("b", ("delta", GCounter((("b", 1),)), 1, None))
    assert r.entries[0].origin == "b"
    cap.sent.clear()
    r._ship_to("c")              # c never saw it: full payload
    (msg,) = _deltas_to(cap, "c")
    assert msg[1].value() == 1
    r._ship_to("b")              # back to origin: ⊥ payload, ack still moves
    (msg,) = _deltas_to(cap, "b")
    assert msg[1] == GCounter.bottom()
    assert msg[2] == r.c         # tagged so b's ack advances the horizon


def test_bp_basic_mode_skips_origin_entirely():
    cap = _CaptureSim()
    r = BasicNode("a", GCounter.bottom(), ["b", "c"],
                  policy=AvoidBackPropagation())
    r.attach(cap)
    r.on_receive("b", ("delta", GCounter((("b", 1),))))
    r.on_periodic()
    assert _deltas_to(cap, "c")          # forwarded onward
    assert not _deltas_to(cap, "b")      # no ack machinery ⇒ no send at all


# ---------------------------------------------------------------------------
# RR: part-wise trimming against the ack-derived known state
# ---------------------------------------------------------------------------

def test_rr_trims_atoms_the_receiver_acked():
    cap = _CaptureSim()
    r = CausalNode("a", GCounter.bottom(), ["b"], policy=RemoveRedundant())
    r.attach(cap)
    r.operation(lambda X: X.inc_delta("a"))
    r._ship_to("b")
    r.on_receive("b", ("ack", r.c))          # b now provably holds {a:1}
    assert r.known_state("b") == GCounter((("a", 1),))
    # a redundant-in-part delta arrives: {a:1} ⊔ {z:1}
    r.on_receive("z", ("delta", GCounter((("a", 1), ("z", 1))), 5, None))
    cap.sent.clear()
    r._ship_to("b")
    (msg,) = _deltas_to(cap, "b")
    # the {a:1} part was trimmed; only the fresh atom ships
    assert msg[1] == GCounter((("z", 1),))


def test_rr_known_state_credits_full_state_fallback():
    cap = _CaptureSim()
    r = CausalNode("a", GCounter.bottom(), ["b"], policy=RemoveRedundant())
    r.attach(cap)
    for _ in range(5):
        r.operation(lambda X: X.inc_delta("a"))
    r.gc_deltas()
    r.entries.clear()                        # simulate GC'd-past horizon
    r._ship_to("b")                          # ⇒ full-state fallback
    (msg,) = _deltas_to(cap, "b")
    assert msg[1] == r.X
    r.on_receive("b", ("ack", msg[2]))
    # the ack credited the *payload*, not just (empty) buffered entries
    assert r.known_state("b") == r.X


# ---------------------------------------------------------------------------
# Every policy converges to the same state; BP/RR bytes ≤ ship-all
# ---------------------------------------------------------------------------

def _run_policy(spec, bottom_fn, op, loss=0.25, dup=0.15, n_ops=40,
                crash=False):
    sim = Simulator(NetConfig(loss=loss, dup=dup, seed=9))
    ids = [f"n{k}" for k in range(4)]
    nodes = [sim.add_node(CausalNode(
        i, bottom_fn(), [j for j in ids if j != i],
        rng=random.Random(13), ghost_check=True,
        policy=make_policy(spec))) for i in ids]
    rng = random.Random(17)
    for k in range(n_ops):
        n = rng.choice(nodes)
        if n.alive:
            op(n, rng)
        sim.run_for(0.4)
        if crash and k == n_ops // 2:
            sim.crash(ids[0], downtime=4.0)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    fails = [f for n in nodes for f in n.ghost_failures]
    assert not fails, fails
    bytes_shipped = sim.stats.payload_atoms()
    return nodes[0].X, bytes_shipped


@pytest.mark.parametrize("crash", [False, True])
def test_policies_converge_identically_and_bp_rr_never_ship_more(crash):
    def inc(n, rng):
        n.operation(lambda X, i=n.id: X.inc_delta(i))

    results = {spec: _run_policy(spec, GCounter.bottom, inc, crash=crash)
               for spec in POLICY_SPECS}
    states = [x for x, _ in results.values()]
    assert all(s == states[0] for s in states[1:])
    base = results["all"][1]
    assert results["bp"][1] <= base
    assert results["rr"][1] <= base
    assert results["bp+rr"][1] <= base
    assert results["bp+rr"][1] < base     # strict on this topology


def test_policies_converge_on_orset_workload():
    def addrm(n, rng):
        if rng.random() < 0.7:
            n.operation(lambda X, i=n.id: X.add_delta(i, rng.choice("xyz")))
        else:
            n.operation(lambda X, i=n.id: X.rmv_delta(i, rng.choice("xyz")))

    results = {spec: _run_policy(spec, AWORSet.bottom, addrm)
               for spec in ("all", "bp", "bp+rr")}
    states = [x for x, _ in results.values()]
    assert all(s == states[0] for s in states[1:])
    assert results["bp+rr"][1] <= results["all"][1]


# ---------------------------------------------------------------------------
# DigestBudget: basic-mode only, budget respected
# ---------------------------------------------------------------------------

def test_digest_budget_rejected_in_causal_mode():
    with pytest.raises(ValueError):
        CausalNode("a", GCounter.bottom(), ["b"],
                   policy=DigestBudget(1024))
    with pytest.raises(ValueError):
        Replica("a", GCounter.bottom(), ["b"], causal=True,
                policy=make_policy("digest:1024+every:5"))


def test_digest_budget_converges_with_periodic_full_state():
    from repro.core.tensor_lattice import TensorState

    sim = Simulator(NetConfig(loss=0.0, dup=0.0, seed=3))
    ids = ["n0", "n1"]
    chunk = 8
    budget = 2 * (chunk * 4 + 8 + 4)     # two f32 chunks + version + index
    nodes = [sim.add_node(BasicNode(
        i, TensorState.bottom(), [j for j in ids if j != i],
        policy=make_policy(f"digest:{budget}+every:4"))) for i in ids]
    rng = np.random.default_rng(0)
    for k in range(6):
        vals = rng.normal(size=(32,)).astype(np.float32)
        nodes[0].operation(lambda X, v=vals: X.write_delta(
            0, "w", v, chunk_size=chunk))
        sim.run_for(2.0)
    run_to_convergence(sim, nodes, interval=1.0, max_time=10_000)
    assert converged(nodes)


def test_digest_budget_caps_payload_size():
    from repro.core.tensor_lattice import TensorState, digest_select

    s = TensorState.bottom().write_delta(
        0, "w", np.arange(64, dtype=np.float32), chunk_size=8)
    per_chunk = 8 * 4 + 8 + 4
    sel = digest_select(s, budget_bytes=3 * per_chunk)
    live = np.asarray(sel.as_dict()["w"].versions) > 0
    assert live.sum() == 3
    assert set(np.nonzero(live)[0]) == {5, 6, 7}   # top energy chunks
    assert sel.leq(s)
    assert s.join(sel) == s                        # never invents state


# ---------------------------------------------------------------------------
# Wrapper compatibility with the paper-facing API
# ---------------------------------------------------------------------------

def test_basic_node_delta_group_view_and_recovery():
    r = BasicNode("a", GCounter.bottom(), ["b"], ship_state_every=3)
    r.operation(lambda X: X.inc_delta("a"))
    assert r.D == GCounter((("a", 1),))
    r.crash_and_recover()
    assert r.X.value() == 1                   # durable
    assert r.D == GCounter.bottom()           # volatile

def test_causal_node_interval_view_and_recovery():
    r = CausalNode("a", GCounter.bottom(), ["b"])
    r.operation(lambda X: X.inc_delta("a"))
    r.operation(lambda X: X.inc_delta("a"))
    assert set(r.D) == {0, 1} and r.c == 2
    r.A["b"] = 1
    r.crash_and_recover()
    assert (r.X.value(), r.c) == (2, 2)       # durable (X, c)
    assert r.D == {} and r.A == {}            # volatile


def test_ship_state_every_k_in_causal_mode_forces_full_state():
    cap = _CaptureSim()
    r = CausalNode("a", GCounter.bottom(), ["b"],
                   policy=ShipStateEveryK(1))
    r.attach(cap)
    r.operation(lambda X: X.inc_delta("a"))
    r.on_receive("b", ("delta", GCounter((("b", 4),)), 1, None))
    r.rounds = 1
    r._ship_to("b")
    (msg,) = _deltas_to(cap, "b")
    assert msg[1] == r.X                      # full X, not the interval


# ---------------------------------------------------------------------------
# Basic-mode fanout: deltas must survive until EVERY neighbor got them
# ---------------------------------------------------------------------------

def test_basic_fanout_retains_unshipped_deltas():
    """Regression: on_periodic used to clear the whole delta-group after
    broadcasting to only the fanout-sampled targets, permanently dropping
    the deltas for every unsampled neighbor."""
    from repro.core import GSet

    cap = _CaptureSim()
    r = Replica("a", GSet.bottom(), ["b", "c"], causal=False, fanout=1,
                transitive=False, rng=random.Random(0))
    r.attach(cap)
    r.operation(lambda X: X.add_delta("e0"))
    r.on_periodic()
    (first_dst,) = {d for _, d, _ in cap.sent}
    # the entry survives for the neighbor that was NOT sampled
    assert len(r.entries) == 1
    other = ({"b", "c"} - {first_dst}).pop()
    for _ in range(20):
        r.on_periodic()
        if not r.entries:
            break
    assert not r.entries                     # dropped only once both got it
    delta_payloads = [(d, m[1]) for _, d, m in cap.sent
                      if m[0] == "delta" and m[1].elements()]
    assert {d for d, _ in delta_payloads} == {"b", "c"}
    assert all(p.elements() == {"e0"} for _, p in delta_payloads
               if p.elements())
    assert other in {d for d, _ in delta_payloads}


def test_basic_fanout_no_delta_loss_end_to_end():
    """A continuously-writing basic replica with fanout sampling: every
    element reaches BOTH silent neighbors as deltas (no reliance on the
    empty-buffer full-state round to paper over the loss). Fails on the
    clear-after-broadcast behavior, where each element only ever reached
    the one sampled neighbor (~half the set each)."""
    from repro.core import GSet

    sim = Simulator(NetConfig(loss=0.0, seed=3))
    a = sim.add_node(Replica("a", GSet.bottom(), ["b", "c"], causal=False,
                             fanout=1, transitive=False,
                             rng=random.Random(5)))
    b = sim.add_node(Replica("b", GSet.bottom(), [], causal=False))
    c = sim.add_node(Replica("c", GSet.bottom(), [], causal=False))
    R = 40
    for r in range(R):
        a.operation(lambda X, r=r: X.add_delta(f"e{r}"))
        a.on_periodic()
        sim.run_for(2.0)
    for n in (b, c):
        missing = {f"e{r}" for r in range(R - 12)} - n.X.elements()
        assert not missing, f"{n.id} permanently missed deltas: {missing}"


def test_basic_full_broadcast_still_clears_buffer_each_round():
    """fanout=None (broadcast to all): the per-destination watermarks
    reduce exactly to Algorithm 1's clear-after-broadcast."""
    from repro.core import GSet

    cap = _CaptureSim()
    r = Replica("a", GSet.bottom(), ["b", "c"], causal=False,
                transitive=False, rng=random.Random(0))
    r.attach(cap)
    r.operation(lambda X: X.add_delta("e0"))
    r.on_periodic()
    assert not r.entries
    assert {d for _, d, _ in cap.sent} == {"b", "c"}


# ---------------------------------------------------------------------------
# choose(): the paper-facing preview, across the whole policy matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", list(POLICY_SPECS)
                         + ["digest:4096", "every:3+bp", "digest-sync:4",
                            "bp+rr+digest-sync:4"])
def test_choose_matches_shipment_across_policy_matrix(spec):
    """For every policy: choose(dst) equals what on_periodic actually
    posts to dst, and the generic choose() (dst=None — never the
    empty-string pseudo-id, which is a legal replica name) returns the
    X-or-D preview without consulting per-destination state."""
    from repro.core import GSet

    cap = _CaptureSim()
    r = BasicNode("a", GSet.bottom(), ["b", "c"], policy=make_policy(spec))
    r.attach(cap)
    # remote-origin entry (exercises BP), then a local one
    r.on_receive("b", ("delta", GSet(frozenset({"remote"}))))
    r.operation(lambda X: X.add_delta("local"))
    generic = r.choose()
    per_dst = {dst: r.choose(dst) for dst in ("b", "c")}
    cap.sent.clear()
    r.on_periodic()
    posted = {d: m for _, d, m in cap.sent}
    for dst in ("b", "c"):
        want = per_dst[dst]
        if isinstance(want, tuple):          # pull round: digest request
            assert posted[dst][0] == "digest"
            assert posted[dst][1] == want[1]
        elif want == GSet.bottom():          # all filtered ⇒ nothing sent
            assert dst not in posted
        else:
            assert posted[dst][1] == want
    # generic preview is X or D (or the digest request on pull rounds)
    if isinstance(generic, tuple):
        assert generic[0] == "digest"
    else:
        assert generic in (r.X, r.D)


def test_choose_generic_is_safe_for_empty_string_replica_id():
    """A neighbor literally named "" must not leak its per-destination
    state into the generic preview (the old dst="" sentinel did)."""
    from repro.core import GSet

    cap = _CaptureSim()
    r = BasicNode("a", GSet.bottom(), ["", "c"],
                  policy=make_policy("bp+rr"))
    r.attach(cap)
    r.operation(lambda X: X.add_delta("x"))
    r._known[""] = r.X                       # "" provably holds everything
    assert r.choose() == r.D                 # generic preview unaffected
    assert r.choose("") == GSet.bottom()     # per-dst preview IS affected
    r._basic_sent[""] = r.c                  # already broadcast to ""
    assert r.choose("") == r.X               # ⇒ the full-state fallback
