"""Property tests (hypothesis) for the shipping-policy layer:

* every ShippingPolicy preserves convergence under loss / duplication /
  reordering, on every datatype adapter, with the Prop. 2 ghost-check on;
* AvoidBackPropagation / RemoveRedundant ship monotonically ≤ ShipAll's
  structural bytes on the identical seeded execution;
* RemoveRedundant never ships an atom the receiver provably covers
  (checked at every send against the sender's ack-derived known state);
* decompose() is a faithful join-decomposition where implemented.
"""

import random

import pytest
import pytest as _pytest
_pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from crdt_adapters import ADAPTERS, random_reachable_states
from repro.core import (CausalNode, GCounter, NetConfig, POLICY_SPECS,
                        Simulator, StoreReplica, converged, make_policy,
                        run_to_convergence)

POLICY_ADAPTERS = ["gcounter", "pncounter", "aworset", "ormap", "mvreg"]


def _drive(spec, name, seed, n_nodes=3, n_ops=15):
    ad = ADAPTERS[name]
    rng = random.Random(seed)
    sim = Simulator(NetConfig(loss=0.25, dup=0.15, seed=seed))
    ids = [f"n{k}" for k in range(n_nodes)]
    nodes = [sim.add_node(CausalNode(
        i, ad.bottom, [j for j in ids if j != i],
        rng=random.Random(seed + 1), ghost_check=True,
        policy=make_policy(spec))) for i in ids]
    for _ in range(n_ops):
        n = rng.choice(nodes)
        op = rng.choice(ad.ops)
        args = op.make_args(rng)
        n.operation(lambda X, i=n.id, op=op, args=args:
                    op.delta(X, i, *args))
        if rng.random() < 0.5:
            sim.run_for(0.5)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    fails = [f for n in nodes for f in n.ghost_failures]
    assert not fails, fails
    payload = sim.stats.payload_atoms()
    return nodes[0].X, payload


@pytest.mark.parametrize("spec", POLICY_SPECS)
@pytest.mark.parametrize("name", POLICY_ADAPTERS)
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_every_policy_converges_under_loss_dup_reorder(spec, name, seed):
    _drive(spec, name, seed)


@pytest.mark.parametrize("name", ["gcounter", "aworset"])
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_bp_rr_bytes_monotonically_below_ship_all(name, seed):
    """Same seeded execution ⇒ same converged state; filtering policies
    never ship more structural bytes than the ship-all baseline."""
    base_state, base_bytes = _drive("all", name, seed)
    for spec in ("bp", "rr", "bp+rr"):
        state, payload = _drive(spec, name, seed)
        assert state == base_state
        assert payload <= base_bytes, (
            f"{spec} shipped {payload} > ship-all {base_bytes}")


class _AuditedSim(Simulator):
    """Asserts, at every delta send, that no shipped atom is provably
    already covered by the receiver (the RR guarantee)."""

    def send(self, src, dst, msg):
        if isinstance(msg, tuple) and msg and msg[0] == "delta":
            node = self.nodes.get(src)
            payload = msg[1]
            known = node.known_state(dst) if node is not None else None
            atoms = getattr(payload, "decompose", None)
            if known is not None and atoms is not None:
                for a in atoms():
                    assert not a.leq(known), (
                        f"{src}->{dst}: shipped atom {a!r} already covered")
        super().send(src, dst, msg)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rr_never_ships_a_covered_atom(seed):
    rng = random.Random(seed)
    sim = _AuditedSim(NetConfig(loss=0.2, dup=0.1, seed=seed))
    ids = [f"n{k}" for k in range(3)]
    nodes = [sim.add_node(CausalNode(
        i, GCounter.bottom(), [j for j in ids if j != i],
        rng=random.Random(seed + 1), policy=make_policy("bp+rr")))
        for i in ids]
    for k in range(20):
        n = rng.choice(nodes)
        if n.alive:
            n.operation(lambda X, i=n.id: X.inc_delta(i))
        sim.run_for(0.5)
        if k == 10:
            sim.crash(ids[0], downtime=3.0)   # forces fallback re-gossip
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)


@pytest.mark.parametrize("spec", POLICY_SPECS)
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_keyed_store_converges_under_every_policy(spec, seed):
    """Store-backed replicas: random multi-key workloads (mixed embedded
    datatypes per key) converge under loss/dup/reorder with every
    shipping policy, with the Prop. 2 ghost-check on."""
    rng = random.Random(seed)
    sim = Simulator(NetConfig(loss=0.25, dup=0.15, seed=seed))
    ids = [f"n{k}" for k in range(3)]
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True, ghost_check=True,
        rng=random.Random(seed + 1), policy=make_policy(spec)))
        for i in ids]
    key_types = {f"k{j}": ADAPTERS[name] for j, name in enumerate(
        ["gcounter", "aworset", "ormap", "mvreg"])}
    for _ in range(15):
        n = rng.choice(nodes)
        key = rng.choice(list(key_types))
        ad = key_types[key]
        op = rng.choice(ad.ops)
        args = op.make_args(rng)
        n.operation(lambda S, i=n.id, key=key, ad=ad, op=op, args=args:
                    S.update_delta(key, type(ad.bottom),
                                   lambda v: op.delta(v, i, *args)))
        if rng.random() < 0.5:
            sim.run_for(0.5)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    fails = [f for n in nodes for f in n.ghost_failures]
    assert not fails, fails


@pytest.mark.parametrize("name", ["gcounter", "pncounter"])
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_decompose_is_a_faithful_join_decomposition(name, seed):
    """⊔ decompose(X) == X, and every atom is ≤ X."""
    ad = ADAPTERS[name]
    rng = random.Random(seed)
    X = rng.choice(random_reachable_states(ad, rng, n_ops=10))
    atoms = X.decompose()
    rejoined = ad.bottom
    for a in atoms:
        assert a.leq(X)
        rejoined = rejoined.join(a)
    assert rejoined == X


# ---------------------------------------------------------------------------
# Digest-driven pull sync (request/response anti-entropy)
# ---------------------------------------------------------------------------

def _drive_partitioned(spec, name, seed, n_nodes=3, n_ops=12):
    """Same seeded workload under loss + duplication + a partition window
    (the reconnect scenario digest-sync targets)."""
    ad = ADAPTERS[name]
    rng = random.Random(seed)
    sim = Simulator(NetConfig(loss=0.2, dup=0.1, seed=seed))
    ids = [f"n{k}" for k in range(n_nodes)]
    sim.add_partition(3.0, 10.0, ids[:1], ids[1:])
    nodes = [sim.add_node(CausalNode(
        i, ad.bottom, [j for j in ids if j != i],
        rng=random.Random(seed + 1), ghost_check=True,
        policy=make_policy(spec))) for i in ids]
    for _ in range(n_ops):
        n = rng.choice(nodes)
        op = rng.choice(ad.ops)
        args = op.make_args(rng)
        n.operation(lambda X, i=n.id, op=op, args=args:
                    op.delta(X, i, *args))
        if rng.random() < 0.5:
            sim.run_for(0.5)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    assert not [f for n in nodes for f in n.ghost_failures]
    return nodes[0].X


@pytest.mark.parametrize("name", ["gcounter", "aworset"])
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_digest_sync_state_equals_full_antientropy_under_partition(
        name, seed):
    """Pure pull converges to exactly the state push-everything reaches
    on the identical seeded workload, through loss / duplication /
    reordering / a healing partition."""
    x_pull = _drive_partitioned("digest-sync", name, seed)
    x_push = _drive_partitioned("all", name, seed)
    assert x_pull == x_push


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_digest_response_never_ships_a_dominated_row(seed):
    """For random divergent tensor stores: every chunk row in a digest
    response strictly dominates the requester's version at that position,
    the wire-level known_versions filter agrees exactly with the
    object-mode digest_diff oracle, and joining the response equals
    joining the responder's full state."""
    import numpy as np

    from repro.core import LatticeStore, digest_diff, store_digest
    from repro.core.tensor_lattice import TensorState, chunk_tensor
    from repro.wire import decode_store, encode_store

    rng = random.Random(seed)
    base = LatticeStore.of({
        f"k{i}": TensorState.of({"w": chunk_tensor(
            np.arange(24, dtype=np.float32), 8, version=1)})
        for i in range(3)})

    def mutate(store, rank):
        for _ in range(rng.randrange(0, 6)):
            key = f"k{rng.randrange(3)}"
            ts = store.get(key, TensorState)
            d = ts.write_delta(rank, "w",
                               np.full((1, 8), rng.random(), np.float32),
                               chunk_idx=np.array([rng.randrange(3)]))
            store = store.join(LatticeStore.key_delta(key, d))
        return store

    requester = mutate(base, 1)
    responder = mutate(base, 2)
    dig = store_digest(requester)
    resp = digest_diff(responder, dig)
    for key in resp.keys():
        for name, ct in resp.get(key).chunks:
            idx = np.asarray(ct.idx)
            vers = np.asarray(ct.vers)
            known = dig.tensors[(key, name)]
            assert np.all(vers > known[idx]), (
                f"{key}/{name}: shipped a row the requester dominates")
    assert requester.join(resp) == requester.join(responder)
    wire_resp = decode_store(encode_store(
        responder, known_versions=dig.tensors, known_opaque=dig.opaque))
    assert wire_resp == resp
