"""Device-resident store columns (kernels.resident): parity of the
one-launch resident paths against the host-staged paths and the per-key
loop oracle, counter accounting (O(1) launches, delta-only staging),
cache survival across reaps/handoffs via re-adoption, the in-place
stacked patch path, the digest memo, and resident replicas over the
device-decoding wire."""

import numpy as np
import pytest

from repro.core.digest import store_digest
from repro.core.store import LatticeStore, digest_select_store
from repro.core.tensor_lattice import (ChunkedTensor, TensorState,
                                       sparse_chunks)
from repro.kernels import ops, resident

CHUNK = 32
ROW_BYTES = CHUNK * 4 + 12          # f32 payload + i64 index + i32 version


def _mk_store(sizes, chunk=CHUNK, seed=0, version=1, n_tensors=1,
              dtype=np.float32):
    rng = np.random.default_rng(seed)
    out = {}
    for i, n in enumerate(sizes):
        ts = {}
        for t in range(n_tensors):
            vals = rng.normal(size=(n, chunk)).astype(dtype)
            vers = (rng.integers(0, 3, size=(n,)).astype(np.int32) * 2
                    + version)
            ts[f"t{t}"] = ChunkedTensor(vals, vers)
        out[f"k{i}"] = TensorState.of(ts, lamport=version)
    return LatticeStore.of(out)


def _mk_sparse_delta(touch, n_chunks, chunk=CHUNK, seed=100, version=9,
                     n_tensors=1, dtype=np.float32):
    rng = np.random.default_rng(seed)
    out = {}
    for key in touch:
        ts = {}
        for t in range(n_tensors):
            r = min(2, n_chunks)
            idx = np.sort(rng.choice(n_chunks, size=r,
                                     replace=False)).astype(np.int32)
            vals = rng.normal(size=(r, chunk)).astype(dtype)
            vers = np.full((r,), version * 2 + 1, np.int32)
            ts[f"t{t}"] = sparse_chunks(n_chunks, idx, vals, vers)
        out[key] = TensorState.of(ts, lamport=version)
    return LatticeStore.of(out)


def _stores_equal(a, b):
    assert store_digest(a) == store_digest(b)
    for (k, va), (k2, vb) in zip(a.entries, b.entries):
        assert k == k2
        for (n, ca), (n2, cb) in zip(va.chunks, vb.chunks):
            assert n == n2
            np.testing.assert_allclose(np.asarray(ca.values),
                                       np.asarray(cb.values), rtol=1e-6)
            np.testing.assert_array_equal(np.asarray(ca.versions),
                                          np.asarray(cb.versions))


# ---------------------------------------------------------------------------
# Join parity: resident ≡ host-staged ≡ per-key loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes", [
    [4, 4, 4, 4],                   # uniform rows
    [1, 3, 7, 13, 5],               # ragged chunk counts
    [8],                            # single key
])
def test_scatter_ingest_matches_loop_join(sizes):
    a = _mk_store(sizes, seed=0)
    d = _mk_sparse_delta([f"k{i}" for i in range(0, len(sizes), 2)],
                         n_chunks=min(sizes), seed=7)
    # the delta's tensors must exist within each key's layout: regenerate
    # per-key with the right chunk count
    d = LatticeStore.of({
        k: _mk_sparse_delta([k], sizes[int(k[1:])], seed=7 + i).get(k)
        for i, k in enumerate(f"k{j}" for j in range(0, len(sizes), 2))})
    assert resident.ensure(a) is not None
    got = a.join(d)
    assert resident.resident_of(got) is not None
    ref = LatticeStore(a.entries, a.life).join(d, batched=False)
    _stores_equal(got, ref)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_scatter_ingest_dtype_parity(dtype):
    a = _mk_store([3, 5, 2], seed=1, dtype=dtype)
    d = _mk_sparse_delta(["k1"], 5, seed=8, dtype=dtype)
    assert resident.ensure(a) is not None
    got = a.join(d)
    ref = LatticeStore(a.entries, a.life).join(d, batched=False)
    _stores_equal(got, ref)


def test_aligned_resident_join_matches_loop():
    a = _mk_store([3, 5, 2], seed=2, version=1, n_tensors=2)
    b = _mk_store([3, 5, 2], seed=3, version=5, n_tensors=2)
    resident.ensure(a)
    resident.ensure(b)
    snap = ops.counters.snapshot()
    got = a.join(b)
    d = ops.counters.since(snap)
    assert d["launches"] == 1 and d["h2d_bytes"] == 0
    assert resident.resident_of(got) is not None
    ref = LatticeStore(a.entries, a.life).join(
        LatticeStore(b.entries, b.life), batched=False)
    _stores_equal(got, ref)


def test_resident_rounds_chain_without_readoption():
    """Round N's result carries the cache round N+1 ingests into — no
    re-stack, no re-upload, one launch each round."""
    s = _mk_store([4, 4, 4], seed=4)
    resident.ensure(s)
    for rnd in range(4):
        d = _mk_sparse_delta(["k1"], 4, seed=20 + rnd, version=10 + rnd)
        snap = ops.counters.snapshot()
        s = s.join(d)
        diff = ops.counters.since(snap)
        assert diff["launches"] == 1
        assert resident.resident_of(s) is not None
    ref = _mk_store([4, 4, 4], seed=4)
    for rnd in range(4):
        ref = ref.join(_mk_sparse_delta(["k1"], 4, seed=20 + rnd,
                                        version=10 + rnd), batched=False)
    _stores_equal(s, ref)


def test_ingest_launches_are_size_independent():
    """Same delta against a 4x bigger store: identical launch count, and
    staged bytes bounded by the delta (not the store)."""
    def round_cost(n_keys):
        a = _mk_store([4] * n_keys, seed=5)
        resident.ensure(a)
        d = _mk_sparse_delta(["k0", "k1"], 4, seed=30)
        snap = ops.counters.snapshot()
        a.join(d)
        return ops.counters.since(snap)
    small, big = round_cost(8), round_cost(32)
    assert small["launches"] == big["launches"] == 1
    delta_bytes = 2 * 2 * (CHUNK * 4 + 4)     # 2 keys × 2 rows: vals+vers
    pad = 16 * (CHUNK * 4 + 4) + 16 * 4       # padded grid bucket + idx
    assert big["h2d_bytes"] <= delta_bytes + pad
    assert big["h2d_bytes"] == small["h2d_bytes"]


# ---------------------------------------------------------------------------
# Digest summaries and energy selection from the maintained columns
# ---------------------------------------------------------------------------

def test_store_digest_served_from_resident_matches_plain():
    a = _mk_store([3, 5, 2], seed=6, n_tensors=2)
    plain = store_digest(LatticeStore(a.entries, a.life))
    resident.ensure(a)
    s = a.join(_mk_sparse_delta(["k2"], 2, seed=31))
    ref = LatticeStore(a.entries, a.life).join(
        _mk_sparse_delta(["k2"], 2, seed=31), batched=False)
    assert store_digest(s) == store_digest(ref)
    assert store_digest(a) == plain           # old snapshot stays valid


def test_keep_plan_matches_host_digest_selection():
    a = _mk_store([6, 6, 6], seed=7, n_tensors=2)
    host = digest_select_store(LatticeStore(a.entries, a.life),
                               10 * ROW_BYTES)
    resident.ensure(a)
    dev = digest_select_store(a, 10 * ROW_BYTES)
    _stores_equal(dev, host)


def test_keep_plan_none_when_budget_covers_everything():
    a = _mk_store([2, 2], seed=8)
    resident.ensure(a)
    assert digest_select_store(a, 10 ** 9) is a


# ---------------------------------------------------------------------------
# Cache lifecycle: spill, reap, handoff, layout drift
# ---------------------------------------------------------------------------

def test_spill_roundtrip_restores_host_cache():
    a = _mk_store([3, 4], seed=9)
    resident.ensure(a)
    snap = ops.counters.snapshot()
    sc = resident.spill(a)
    assert ops.counters.since(snap)["d2h_bytes"] >= sc.vals.nbytes
    from repro.core.store import _StackedChunks
    assert isinstance(sc, _StackedChunks)
    assert store_digest(a) == store_digest(
        LatticeStore(a.entries, a.life))


def test_tombstoned_key_falls_back_then_readopts():
    """An epoch bump (reap) breaks the fast-path gate; the join still
    converges via the general path and the next ensure() re-adopts the
    post-reap layout."""
    a = _mk_store([3, 4, 5], seed=10)
    resident.ensure(a)
    reaped = LatticeStore(
        tuple((k, v) for k, v in a.entries if k != "k0"),
        (("k0", (1, float("-inf"))),))
    got = a.join(reaped)
    ref = LatticeStore(a.entries, a.life).join(reaped, batched=False)
    _stores_equal(got, ref)
    cache = resident.ensure(got)
    assert cache is not None
    assert ("k0", "t0") not in cache.spans
    assert store_digest(got) == store_digest(ref)


def test_handoff_restriction_readopts_remaining_keys():
    a = _mk_store([3, 4, 5], seed=11)
    resident.ensure(a)
    rest = a.restrict(["k1", "k2"])
    cache = resident.ensure(rest)
    assert cache is not None
    assert set(k for k, _, _, _ in cache.layout) == {"k1", "k2"}
    assert store_digest(rest) == store_digest(
        LatticeStore(rest.entries, rest.life))


def test_layout_drift_new_key_falls_back_to_host_paths():
    a = _mk_store([3, 4], seed=12)
    resident.ensure(a)
    d = _mk_store([2], seed=13, version=7)      # brings key k0 of size 2…
    d = LatticeStore.of({"brand-new": d.get("k0")})   # …as a NEW key
    got = a.join(d)
    ref = LatticeStore(a.entries, a.life).join(d, batched=False)
    _stores_equal(got, ref)
    assert resident.ensure(got) is not None     # re-adopt picks it up


def test_adopt_densifies_sparse_receiver_state():
    """A store whose tensors arrived entirely as wire deltas holds
    SparseChunks — adopt densifies them into the columns."""
    d = _mk_sparse_delta(["k0", "k1"], 4, seed=14)
    s = LatticeStore.bottom().join(d)
    cache = resident.ensure(s)
    assert cache is not None
    assert store_digest(s) == store_digest(LatticeStore(s.entries, s.life))


# ---------------------------------------------------------------------------
# Satellite 1: in-place patch of the host stacked cache
# ---------------------------------------------------------------------------

def _stacked(store):
    sc = store.__dict__.get("_stacked_cache")
    from repro.core.store import _StackedChunks
    return sc if isinstance(sc, _StackedChunks) else None


def test_patched_stacked_join_matches_loop_and_reuses_untouched():
    a = _mk_store([4, 4, 4], seed=15)
    b = _mk_store([4, 4, 4], seed=16, version=3)
    j = a.join(b)                       # aligned fast join attaches cache
    assert _stacked(j) is not None
    d = _mk_sparse_delta(["k1"], 4, seed=32)
    j2 = j.join(d)
    ref = LatticeStore(j.entries, j.life).join(d, batched=False)
    _stores_equal(j2, ref)
    # untouched keys keep their entry objects (no full rebuild) and the
    # result carries a patched cache with the identical layout
    assert _stacked(j2) is not None
    assert _stacked(j2).layout == _stacked(j).layout
    e1, e2 = dict(j.entries), dict(j2.entries)
    assert e2["k0"] is e1["k0"] and e2["k2"] is e1["k2"]
    assert e2["k1"] is not e1["k1"]


def test_patched_stacked_join_rejects_layout_change():
    a = _mk_store([4, 4], seed=17)
    b = _mk_store([4, 4], seed=18, version=3)
    j = a.join(b)
    assert _stacked(j) is not None
    d = LatticeStore.of({"kX": _mk_store([2], seed=19).get("k0")})
    j2 = j.join(d)
    ref = LatticeStore(j.entries, j.life).join(d, batched=False)
    _stores_equal(j2, ref)


# ---------------------------------------------------------------------------
# Satellite 2: digest memo on untouched tensors
# ---------------------------------------------------------------------------

def test_digest_memo_only_recomputes_touched_tensors():
    a = _mk_store([4, 4, 4, 4], seed=20, n_tensors=2)
    b = _mk_store([4, 4, 4, 4], seed=21, version=3, n_tensors=2)
    j = a.join(b)
    budget = 6 * ROW_BYTES
    snap = ops.counters.snapshot()
    digest_select_store(LatticeStore(j.entries, j.life), budget)
    cold = ops.counters.since(snap)["launches"]
    assert cold >= 8                    # one digest per tensor, cold
    digest_select_store(j, budget)      # warm the memo on j's tensors
    d = _mk_sparse_delta(["k1"], 4, seed=33)
    j2 = j.join(d)                      # patched: untouched cts reused
    snap = ops.counters.snapshot()
    digest_select_store(j2, budget)
    warm = ops.counters.since(snap)["launches"]
    assert warm <= 2 + 1                # touched key's tensors + epilogue
    sel = digest_select_store(j2, budget)
    ref = digest_select_store(LatticeStore(j2.entries, j2.life), budget)
    _stores_equal(sel, ref)


# ---------------------------------------------------------------------------
# Wire decode-to-device and resident replicas
# ---------------------------------------------------------------------------

def test_decode_to_device_ingest_stages_only_the_index_column():
    from repro.wire.codec import decode_store, encode_store
    a = _mk_store([4] * 8, seed=22)
    resident.ensure(a)
    d = _mk_sparse_delta(["k0", "k5"], 4, seed=34)
    buf = encode_store(d)
    ddev = decode_store(buf, to_device=True)
    assert ddev.__dict__.get("_device_cols") is not None
    snap = ops.counters.snapshot()
    got = a.join(ddev)
    diff = ops.counters.since(snap)
    assert diff["launches"] == 1
    assert diff["h2d_bytes"] <= 16 * 4      # padded idx column only
    ref = LatticeStore(a.entries, a.life).join(decode_store(buf),
                                               batched=False)
    _stores_equal(got, ref)


def test_resident_replicas_converge_over_device_wire():
    from repro.core.propagation import StoreReplica
    from repro.core.sim import NetConfig, Simulator
    from repro.wire.frames import WireCodec

    def run(resident_mode):
        wc = WireCodec(to_device=resident_mode)
        sim = Simulator(NetConfig(loss=0.1, dup=0.1, seed=23))
        a = sim.add_node(StoreReplica("a", ["b"], causal=False, wire=wc,
                                      resident=resident_mode))
        b = sim.add_node(StoreReplica("b", ["a"], causal=False, wire=wc,
                                      resident=resident_mode))
        rng = np.random.default_rng(24)
        for i in range(5):
            vals = rng.normal(size=(4, CHUNK)).astype(np.float32)
            vers = ((np.arange(4) + 1 + i) * 2 + 1).astype(np.int32)
            a.put(f"k{i}", TensorState.of({"w": ChunkedTensor(vals, vers)},
                                          lamport=1))
        for _ in range(12):
            a.on_periodic()
            b.on_periodic()
            sim.run_for(2.0)
        return a, b

    a, b = run(True)
    assert store_digest(a.store) == store_digest(b.store)
    assert resident.resident_of(a.store) is not None
    assert resident.resident_of(b.store) is not None
    ra, _ = run(False)
    assert store_digest(a.store) == store_digest(ra.store)
