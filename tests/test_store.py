"""Keyed LatticeStore: lattice laws, store-backed replica convergence
under every shipping policy, batched-join parity, store-wide digest
selection, and hash-sharded ownership (rendezvous stability + per-key
convergence + shard-restricted payloads)."""

import random

import numpy as np
import pytest

from repro.core import (Compose, DigestBudget, GCounter, LatticeStore,
                        NetConfig, POLICY_SPECS, PNCounter, Replica,
                        Simulator, StoreReplica, converged,
                        digest_select_store, make_policy,
                        run_to_convergence)
from repro.sync import KeyOwnership, ShardByKey, owners_for_key


def _gc(*pairs):
    return GCounter(tuple(pairs))


# ---------------------------------------------------------------------------
# Lattice laws
# ---------------------------------------------------------------------------

def test_store_join_is_pointwise_and_absorbs_missing_keys():
    a = LatticeStore.of({"k1": _gc(("a", 1))})
    b = LatticeStore.of({"k1": _gc(("b", 2)), "k2": _gc(("b", 1))})
    j = a.join(b)
    assert j.get("k1").value() == 3
    assert j.get("k2").value() == 1
    assert a.leq(j) and b.leq(j) and not j.leq(a)


def test_store_lattice_laws_mixed_types():
    rng = random.Random(7)
    def rand_store():
        out = {}
        for k in range(rng.randint(0, 4)):
            if rng.random() < 0.5:
                out[f"g{k}"] = _gc((rng.choice("abc"), rng.randint(1, 5)))
            else:
                pn = PNCounter.bottom()
                out[f"p{k}"] = pn.inc_delta(rng.choice("abc"),
                                            rng.randint(1, 3))
        return LatticeStore.of(out)
    for _ in range(25):
        A, B, C = rand_store(), rand_store(), rand_store()
        assert A.join(A) == A                          # idempotent
        assert A.join(B) == B.join(A)                  # commutative
        assert A.join(B).join(C) == A.join(B.join(C))  # associative
        assert A.leq(A.join(B))                        # inflationary


def test_bottom_valued_entry_equals_absent_key():
    assert LatticeStore.of({"k": GCounter.bottom()}) == LatticeStore.bottom()
    assert LatticeStore.of({"k": GCounter.bottom()}).leq(LatticeStore.bottom())
    assert LatticeStore.bottom().leq(LatticeStore.of({"k": _gc(("a", 1))}))


def test_store_decompose_is_a_faithful_join_decomposition():
    X = LatticeStore.of({"k1": _gc(("a", 2), ("b", 1)), "k2": _gc(("c", 3))})
    atoms = X.decompose()
    rejoined = LatticeStore.bottom()
    for a in atoms:
        assert a.leq(X)
        assert len(a.keys()) == 1               # per-key (and finer) atoms
        rejoined = rejoined.join(a)
    assert rejoined == X


def test_apply_delta_lifts_embedded_mutators():
    s = LatticeStore.bottom()
    d1 = s.apply_delta("k", GCounter, "inc_delta", "r0")
    s = s.join(d1)
    d2 = s.apply_delta("k", GCounter, "inc_delta", "r0")
    s = s.join(d2)
    assert s.get("k").value() == 2
    assert d2.keys() == frozenset({"k"})


def test_restrict_is_a_lattice_projection():
    X = LatticeStore.of({"a": _gc(("r", 1)), "b": _gc(("r", 2))})
    sub = X.restrict(["a"])
    assert sub.keys() == frozenset({"a"})
    assert sub.leq(X)
    assert X.join(sub) == X


# ---------------------------------------------------------------------------
# Batched TensorState join parity (fast stacked path, general path, loop)
# ---------------------------------------------------------------------------

def _mk_tensor_store(keys, n_tensors=2, n_chunks=3, chunk=128, seed=0,
                     version=1):
    from repro.core.tensor_lattice import ChunkedTensor, TensorState
    rng = np.random.default_rng(seed)
    out = {}
    for k in keys:
        ts = {f"t{t}": ChunkedTensor(
                  rng.normal(size=(n_chunks, chunk)).astype(np.float32),
                  rng.integers(0, 3, size=(n_chunks,)).astype(np.int32)
                  * 2 + version)
              for t in range(n_tensors)}
        out[k] = TensorState.of(ts)
    return LatticeStore.of(out)


def _tensors_equal(a, b):
    for k in set(a.keys()) | set(b.keys()):
        ca, cb = a.get(k).as_dict(), b.get(k).as_dict()
        assert set(ca) == set(cb)
        for name in ca:
            assert np.array_equal(np.asarray(ca[name].values),
                                  np.asarray(cb[name].values))
            assert np.array_equal(np.asarray(ca[name].versions),
                                  np.asarray(cb[name].versions))


def test_batched_join_matches_per_key_loop_aligned():
    keys = [f"k{i}" for i in range(17)]
    a = _mk_tensor_store(keys, seed=0, version=1)
    b = _mk_tensor_store(keys, seed=1, version=2)
    _tensors_equal(a.join(b), a.join(b, batched=False))


def test_batched_join_matches_per_key_loop_subset_delta():
    """Delta touching a subset of keys + a key only present on one side:
    exercises the general segment path, not the aligned fast path."""
    keys = [f"k{i}" for i in range(9)]
    a = _mk_tensor_store(keys, seed=0, version=1)
    b = _mk_tensor_store(keys[:4] + ["extra"], seed=1, version=2)
    _tensors_equal(a.join(b), a.join(b, batched=False))
    _tensors_equal(b.join(a), b.join(a, batched=False))


def test_batched_join_mixed_value_types_falls_back():
    keys = [f"k{i}" for i in range(5)]
    a = _mk_tensor_store(keys, seed=0, version=1)
    a = a.join(LatticeStore.of({"counter": _gc(("r", 1))}))
    b = _mk_tensor_store(keys, seed=1, version=2)
    b = b.join(LatticeStore.of({"counter": _gc(("s", 2))}))
    j = a.join(b)
    _tensors_equal(
        j.restrict(keys), a.join(b, batched=False).restrict(keys))
    assert j.get("counter").value() == 3


def test_batched_join_ragged_chunk_counts():
    """Keys with different chunk counts (not multiples of any block)."""
    from repro.core.tensor_lattice import ChunkedTensor, TensorState
    rng = np.random.default_rng(5)
    def one(n, seed, ver):
        r = np.random.default_rng(seed)
        return TensorState.of({"w": ChunkedTensor(
            r.normal(size=(n, 128)).astype(np.float32),
            np.full((n,), ver, np.int32))})
    a = LatticeStore.of({f"k{i}": one(n, i, 1)
                         for i, n in enumerate([1, 3, 7, 13, 5])})
    b = LatticeStore.of({f"k{i}": one(n, 100 + i, 2)
                         for i, n in enumerate([1, 3, 7, 13, 5])})
    _tensors_equal(a.join(b), a.join(b, batched=False))


# ---------------------------------------------------------------------------
# Store-backed replica: single-object wrapper + keyed convergence
# ---------------------------------------------------------------------------

def test_single_object_replica_is_a_one_key_store():
    r = Replica("a", GCounter.bottom(), ["b"], causal=True)
    r.operation(lambda X: X.inc_delta("a"))
    assert isinstance(r.store, LatticeStore)
    assert r.store.get(Replica.SINGLE_KEY).value() == 1
    assert r.X == _gc(("a", 1))                 # unwrapped view
    r.crash_and_recover()
    assert r.X.value() == 1                     # durable via the store


def test_store_replica_keyed_update_and_get():
    r = StoreReplica("a", ["b"], causal=True)
    r.update("s1", GCounter, "inc_delta", "a")
    r.update("s2", GCounter, "inc_delta", "a")
    r.update("s1", GCounter, "inc_delta", "a")
    assert r.get("s1").value() == 2
    assert r.get("s2").value() == 1
    assert r.get("nope", GCounter).value() == 0
    assert r.keys() == frozenset({"s1", "s2"})


@pytest.mark.parametrize("spec", POLICY_SPECS)
def test_store_replica_converges_under_loss_dup_reorder(spec):
    sim = Simulator(NetConfig(loss=0.25, dup=0.15, seed=42))
    ids = [f"n{k}" for k in range(3)]
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=make_policy(spec), rng=random.Random(43), ghost_check=True))
        for i in ids]
    rng = random.Random(44)
    for t in range(30):
        n = rng.choice(nodes)
        n.update(f"k{t % 6}", GCounter, "inc_delta", n.id)
        if rng.random() < 0.5:
            sim.run_for(0.5)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    assert not [f for n in nodes for f in n.ghost_failures]
    total = sum(nodes[0].get(f"k{j}").value() for j in range(6))
    assert total == 30                          # no write lost or doubled


def test_store_replica_survives_crash_with_durable_store():
    sim = Simulator(NetConfig(loss=0.1, seed=7))
    ids = ["n0", "n1", "n2"]
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=make_policy("bp+rr"), rng=random.Random(8))) for i in ids]
    rng = random.Random(9)
    for t in range(20):
        n = rng.choice(nodes)
        if n.alive:
            n.update(f"k{t % 4}", GCounter, "inc_delta", n.id)
        sim.run_for(0.5)
        if t == 10:
            sim.crash("n0", downtime=3.0)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)


# ---------------------------------------------------------------------------
# Store-wide digest selection
# ---------------------------------------------------------------------------

def test_digest_select_store_picks_keys_by_energy_globally():
    from repro.core.tensor_lattice import ChunkedTensor, TensorState
    def one(scale, n=4, chunk=128):
        vals = np.full((n, chunk), scale, np.float32)
        return TensorState.of({"w": ChunkedTensor(
            vals, np.full((n,), 1, np.int32))})
    store = LatticeStore.of({"hot": one(10.0), "cold": one(0.1),
                             "meta": _gc(("r", 1))})
    per_chunk = 4 * 128 + 8 + 4
    sel = digest_select_store(store, budget_bytes=4 * per_chunk)
    assert sel.leq(store.restrict(["hot", "cold"]).join(
        LatticeStore.of({"meta": _gc(("r", 1))})))
    assert "hot" in sel.keys()                  # all budget went to hot
    assert "cold" not in sel.keys()
    assert sel.get("meta") == _gc(("r", 1))     # non-tensor passes through
    # everything fits ⇒ unchanged
    assert digest_select_store(store, budget_bytes=10 ** 9) == store


def test_digest_budget_policy_applies_across_store_payloads():
    from repro.core.tensor_lattice import ChunkedTensor, TensorState
    def one(scale):
        return TensorState.of({"w": ChunkedTensor(
            np.full((2, 128), scale, np.float32),
            np.full((2,), 1, np.int32))})
    per_chunk = 4 * 128 + 8 + 4
    pol = DigestBudget(budget_bytes=2 * per_chunk)
    r = StoreReplica("a", ["b"], causal=False, policy=pol)
    payload = LatticeStore.of({"hot": one(9.0), "cold": one(0.2)})
    out = pol.finalize(r, "b", payload)
    assert out.keys() == frozenset({"hot"})


# ---------------------------------------------------------------------------
# Rendezvous ownership + sharded shipping
# ---------------------------------------------------------------------------

def test_rendezvous_owners_deterministic_and_spread():
    workers = [f"w{k}" for k in range(5)]
    keys = [f"key{i}" for i in range(200)]
    assign = {k: owners_for_key(k, workers, 2) for k in keys}
    assert assign == {k: owners_for_key(k, list(reversed(workers)), 2)
                      for k in keys}            # order-independent
    per_worker = {w: sum(1 for o in assign.values() if w in o)
                  for w in workers}
    assert all(v > 0 for v in per_worker.values())   # no dead worker


def test_rendezvous_reshuffle_is_minimal_on_leave():
    workers = [f"w{k}" for k in range(6)]
    keys = [f"key{i}" for i in range(300)]
    before = {k: owners_for_key(k, workers, 1)[0] for k in keys}
    after = {k: owners_for_key(k, [w for w in workers if w != "w3"], 1)[0]
             for k in keys}
    moved = [k for k in keys if before[k] != after[k]]
    assert all(before[k] == "w3" for k in moved)   # only the departed's keys
    assert len(moved) == sum(1 for k in keys if before[k] == "w3")


def test_key_ownership_tracks_live_worker_callable():
    live = {"w0", "w1", "w2"}
    own = KeyOwnership(lambda: live, replication=2)
    key = "session-42"
    before = own.owners(key)
    live.add("w3")                              # elastic join re-shuffles
    after = own.owners(key)
    assert len(before) == len(after) == 2
    assert set(after) <= {"w0", "w1", "w2", "w3"}


class _ShardAuditSim(Simulator):
    """Asserts every delta payload only carries keys its destination
    replicates (the ShardByKey guarantee)."""

    def __init__(self, cfg, ownership):
        super().__init__(cfg)
        self.ownership = ownership

    def send(self, src, dst, msg):
        if isinstance(msg, tuple) and msg and msg[0] == "delta":
            payload = msg[1]
            if isinstance(payload, LatticeStore):
                for k in payload.keys():
                    assert self.ownership.replicates(dst, k), \
                        f"{src}->{dst}: shipped non-owned key {k}"
        super().send(src, dst, msg)


def test_sharded_store_converges_per_key_and_ships_only_owned_keys():
    ids = [f"gw{k}" for k in range(4)]
    own = KeyOwnership(ids, replication=2)
    sim = _ShardAuditSim(NetConfig(loss=0.2, dup=0.1, seed=21), own)
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=Compose(make_policy("bp+rr"), ShardByKey(own)),
        rng=random.Random(22), ownership=own)) for i in ids]
    by_id = {n.id: n for n in nodes}
    rng = random.Random(23)
    keys = [f"s{j}" for j in range(10)]
    writes = 0
    for t in range(50):
        n = rng.choice(nodes)       # ingress gateway, often not an owner
        n.update(rng.choice(keys), GCounter, "inc_delta", n.id)
        writes += 1
        sim.run_for(0.4)
    for _ in range(300):
        for n in nodes:
            n.on_periodic()
        sim.run_for(2.0)
        done = all(
            len({repr(by_id[w].get(k, GCounter)) for w in own.owners(k)}) == 1
            for k in keys)
        if done:
            break
    # per-key convergence across each key's replica set...
    total = 0
    for k in keys:
        owners = own.owners(k)
        states = [by_id[w].get(k, GCounter) for w in owners]
        assert all(s == states[0] for s in states[1:]), k
        total += states[0].value()
    # ...and no write lost despite ingress at non-owners + 20% loss
    assert total == writes


def test_sharded_replica_buffers_only_its_shard():
    ids = ["a", "b", "c"]
    own = KeyOwnership(ids, replication=1)
    r = StoreReplica("a", ["b", "c"], causal=True,
                     policy=ShardByKey(own), ownership=own)
    r.attach(_NullSim())
    foreign = next(k for k in (f"x{i}" for i in range(50))
                   if not own.replicates("a", k))
    mine = next(k for k in (f"x{i}" for i in range(50))
                if own.replicates("a", k))
    delta = (LatticeStore.bottom()
             .apply_delta(foreign, GCounter, "inc_delta", "b")
             .join(LatticeStore.bottom()
                   .apply_delta(mine, GCounter, "inc_delta", "b")))
    r.on_receive("b", ("delta", delta, 1, None))
    # joined into X (cheap safety) but buffered only for the owned shard
    assert foreign in r.X.keys()
    buffered = [e.delta for e in r.entries.values() if e.origin == "b"]
    assert buffered and all(foreign not in d.keys() for d in buffered)
    assert any(mine in d.keys() for d in buffered)


# ---------------------------------------------------------------------------
# Bounded per-neighbor bookkeeping (elastic membership, satellite)
# ---------------------------------------------------------------------------

class _NullSim:
    def send(self, src, dst, msg):
        pass


def test_inflight_is_capped_per_destination():
    r = Replica("a", GCounter.bottom(), ["b"], causal=True,
                policy=make_policy("rr"))
    r.attach(_NullSim())
    for _ in range(40):                     # b never acks
        r.operation(lambda X: X.inc_delta("a"))
        r._ship_to("b")
    per_b = [k for k in r._inflight if k[0] == "b"]
    assert len(per_b) <= Replica.INFLIGHT_CAP


def test_departed_neighbors_are_pruned_from_bookkeeping():
    r = Replica("a", GCounter.bottom(), ["b", "c"], causal=True,
                policy=make_policy("rr"))
    r.attach(_NullSim())
    r.operation(lambda X: X.inc_delta("a"))
    r._ship_to("b")
    r._ship_to("c")
    r.on_receive("b", ("ack", r.c))
    r.on_receive("c", ("ack", r.c))
    assert "c" in r.A and "c" in r._known
    r.neighbors.remove("c")                 # elastic departure
    r.gc_deltas()
    assert "c" not in r.A and "c" not in r._known
    assert all(dst != "c" for dst, _ in r._inflight)
    assert "b" in r.A                       # live peer bookkeeping kept


# ---------------------------------------------------------------------------
# Device-resident fast path through the public store API
# ---------------------------------------------------------------------------

def test_join_prefers_resident_cache_and_matches_loop():
    from repro.kernels import resident
    keys = [f"k{i}" for i in range(11)]
    a = _mk_tensor_store(keys, seed=0, version=1)
    b = _mk_tensor_store(keys, seed=1, version=2)
    assert resident.ensure(a) is not None
    j = a.join(b)
    assert resident.resident_of(j) is not None
    _tensors_equal(j, LatticeStore(a.entries, a.life).join(b, batched=False))


def test_digest_select_store_resident_matches_host():
    from repro.core.digest import store_digest
    from repro.kernels import resident
    keys = [f"k{i}" for i in range(6)]
    a = _mk_tensor_store(keys, seed=2, version=1)
    budget = 9 * (128 * 4 + 12)
    host = digest_select_store(LatticeStore(a.entries, a.life), budget)
    resident.ensure(a)
    dev = digest_select_store(a, budget)
    assert store_digest(dev) == store_digest(host)
