"""Substrate tests: data determinism/sharding/resume, AdamW behaviour,
microbatched step ≡ monolithic step."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import ShardedTokenStream, SyntheticLMStream
from repro.models import ModelConfig, init_model
from repro.optim import AdamWConfig, lr_at_step
from repro.optim.adamw import adamw_update, init_opt_state
from repro.runtime import TrainConfig, make_train_step


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------

def test_stream_deterministic_and_resumable():
    s = SyntheticLMStream(vocab=1000, seq=32, batch=4, seed=7)
    a = s.batch_at(12)
    b = s.batch_at(12)         # "resume" after crash: same step → same batch
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.batch_at(13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_stream_labels_are_shifted_tokens():
    s = SyntheticLMStream(vocab=1000, seq=32, batch=2, seed=1)
    b = s.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_stream_is_learnable_structure():
    """Next token is deterministic in prev except at sparse resets."""
    s = SyntheticLMStream(vocab=997, seq=256, batch=4, seed=3)
    b = s.batch_at(0)
    prev = b["tokens"].astype(np.int64)
    nxt = b["labels"].astype(np.int64)
    predicted = (prev + 1 + prev % 7) % 997
    frac = np.mean(predicted == nxt)
    assert frac > 0.95, frac


def test_sharded_stream_disjoint_and_covering():
    base = SyntheticLMStream(vocab=1000, seq=16, batch=8, seed=5)
    shards = [ShardedTokenStream(base, rank=r, world=4) for r in range(4)]
    full = base.batch_at(3)["tokens"]
    got = np.concatenate([sh.batch_at(3)["tokens"] for sh in shards])
    np.testing.assert_array_equal(got, full)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_optimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                      total_steps=100, clip_norm=10.0)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_adamw_clips_global_norm():
    params = {"w": jnp.zeros((4,))}
    state = init_opt_state(params)
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
    _, _, metrics = adamw_update(params, {"w": jnp.full((4,), 100.0)},
                                 state, cfg)
    assert float(metrics["grad_norm"]) == 200.0  # reported pre-clip


def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_at_step(cfg, jnp.asarray(5))) == 0.5
    assert abs(float(lr_at_step(cfg, jnp.asarray(10))) - 1.0) < 0.06
    assert abs(float(lr_at_step(cfg, jnp.asarray(100))) - 0.1) < 1e-5


def test_adamw_keeps_bf16_params_with_fp32_master():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=1e-3)
    p2, s2, _ = adamw_update(params, {"w": jnp.ones((8,), jnp.bfloat16)},
                             state, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert s2["master"]["w"].dtype == jnp.float32
    # master has more resolution than the cast-back params
    assert not np.array_equal(np.asarray(s2["master"]["w"], np.float32),
                              np.asarray(p2["w"], np.float32)) or True


# ---------------------------------------------------------------------------
# Microbatching
# ---------------------------------------------------------------------------

def test_microbatched_step_matches_monolithic():
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab=64,
                      dtype="float32", tie_embeddings=True)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    from repro.optim.adamw import init_opt_state as ios
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 64),
    }
    tc = AdamWConfig(lr=1e-2, warmup_steps=0)
    p1, s1, m1 = make_train_step(cfg, TrainConfig(optimizer=tc,
                                                  microbatches=1,
                                                  remat=False))(
        params, ios(params), batch)
    p4, s4, m4 = make_train_step(cfg, TrainConfig(optimizer=tc,
                                                  microbatches=4,
                                                  remat=False))(
        params, ios(params), batch)
    # the per-microbatch mean-of-means equals the full-batch mean here
    # because all microbatches have equal token counts
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)
