"""Cross-pod δ-CRDT sync runtime: delta-sync training convergence over
lossy links, top-k + error-feedback compression, elastic membership with
straggler eviction, duplicate-safe metrics."""

import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NetConfig, Simulator, converged, run_to_convergence
from repro.core.tensor_lattice import DotSumStore
from repro.sync import (ClusterState, DeltaSyncPod, Membership, Metrics,
                        MetricsState, TopKCompressor)
from repro.sync.compression import dense_nbytes, sparse_nbytes


# ---------------------------------------------------------------------------
# Delta-sync (local SGD) training
# ---------------------------------------------------------------------------

def _init_params():
    return {"w": jnp.zeros((4,), jnp.float32), "b": jnp.zeros((), jnp.float32)}


def _mk_pods(n_pods, loss, seed, compressor_rate=None, ghost=True):
    sim = Simulator(NetConfig(loss=loss, dup=0.1, seed=seed))
    ids = [f"pod{k}" for k in range(n_pods)]

    def local_update(params, round_idx, pod_id):
        # deterministic "training": each pod pushes params toward
        # pod-specific target by 0.5 per round
        k = int(pod_id[3:])
        target = {"w": jnp.full((4,), float(k + 1)),
                  "b": jnp.asarray(float(k))}
        return jax.tree_util.tree_map(
            lambda p, t: p + 0.5 * (t - p), params, target)

    pods = []
    for i in ids:
        comp = TopKCompressor(compressor_rate) if compressor_rate else None
        pods.append(sim.add_node(DeltaSyncPod(
            i, [j for j in ids if j != i], _init_params(), local_update,
            num_pods=n_pods, compressor=comp,
            rng=random.Random(seed + hash(i) % 100), ghost_check=ghost)))
    return sim, pods


def test_delta_sync_pods_converge_over_lossy_network():
    sim, pods = _mk_pods(3, loss=0.3, seed=42)
    for rnd in range(4):
        for p in pods:
            p.do_round()
        sim.run_for(3.0)
    run_to_convergence(sim, pods, interval=1.0, max_time=20_000)
    assert converged(pods)
    # all pods materialize identical outer params
    ps = [p.params() for p in pods]
    for p in ps[1:]:
        assert np.allclose(np.asarray(ps[0]["w"]), np.asarray(p["w"]))
    # every (pod, round) dot was counted exactly once
    assert len(pods[0].X.dots) == 3 * 4
    for n in pods:
        assert not n.ghost_failures


def test_delta_sync_with_topk_compression_converges():
    sim, pods = _mk_pods(3, loss=0.2, seed=7, compressor_rate=0.5,
                         ghost=False)
    for rnd in range(3):
        for p in pods:
            p.do_round()
        sim.run_for(3.0)
    run_to_convergence(sim, pods, interval=1.0, max_time=20_000)
    ps = [p.params() for p in pods]
    for p in ps[1:]:
        assert np.allclose(np.asarray(ps[0]["w"]), np.asarray(p["w"]))


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------

def test_topk_keeps_largest_and_feeds_back_error():
    comp = TopKCompressor(rate=0.25)  # keep 4 of 16
    x = {"g": jnp.asarray(np.arange(16, dtype=np.float32))}
    s = comp.compress(x)
    dense = TopKCompressor.decompress(s)["g"]
    # kept exactly the 4 largest magnitudes
    assert set(np.nonzero(np.asarray(dense))[0]) == {12, 13, 14, 15}
    # residual carries the rest; next round with zero update ships them
    s2 = comp.compress({"g": jnp.zeros(16)})
    dense2 = TopKCompressor.decompress(s2)["g"]
    assert set(np.nonzero(np.asarray(dense2))[0]) == {8, 9, 10, 11}
    # nothing is ever lost: over rounds the sum converges to the original
    total = np.asarray(dense + dense2)
    for _ in range(3):
        total = total + np.asarray(TopKCompressor.decompress(
            comp.compress({"g": jnp.zeros(16)}))["g"])
    assert np.allclose(total, np.arange(16), atol=1e-5)


def test_sparse_payload_smaller_than_dense():
    comp = TopKCompressor(rate=0.01)
    x = {"g": jnp.asarray(np.random.default_rng(0)
                          .normal(size=(4096,)).astype(np.float32))}
    s = comp.compress(x)
    assert sparse_nbytes(s) < dense_nbytes(x) / 10


# ---------------------------------------------------------------------------
# Membership / straggler mitigation
# ---------------------------------------------------------------------------

def test_membership_join_heartbeat_straggler_evict():
    m0 = Membership("w0", timeout=10.0, evict_after=30.0)
    S = ClusterState.bottom()
    S = S.join(m0.announce(S, now=0.0))
    S = S.join(S.join_delta("w1", "w1", 0.0))
    S = S.join(S.join_delta("w2", "w2", 0.0))
    assert S.workers() == {"w0", "w1", "w2"}
    # w2 goes silent; w0/w1 keep beating
    for t in (5.0, 10.0, 15.0, 20.0, 25.0, 31.0):
        S = S.join(S.beat_delta("w0", t)).join(S.beat_delta("w1", t))
    assert S.stragglers(now=31.0, timeout=10.0) == {"w2"}
    assert S.alive(now=31.0, timeout=10.0) == {"w0", "w1"}
    # eviction removes the straggler
    S = S.join(m0.evictions(S, now=31.0))
    assert S.workers() == {"w0", "w1"}


def test_membership_rejoin_wins_over_concurrent_eviction():
    """Add-wins semantics: a pod that rejoins during a partition survives a
    concurrent eviction — elasticity without a coordinator."""
    base = ClusterState.bottom()
    base = base.join(base.join_delta("w0", "w0", 0.0))
    base = base.join(base.join_delta("w1", "w1", 0.0))
    # partition: w0 evicts w1; w1 concurrently re-announces itself
    evict = base.leave_delta("w0", "w1")
    rejoin = base.join_delta("w1", "w1", 50.0)
    healed = base.join(evict).join(rejoin)
    assert "w1" in healed.workers()
    healed2 = base.join(rejoin).join(evict)
    assert healed2 == healed  # order-independent


def test_quorum_barrier_ignores_stragglers():
    m = Membership("w0", timeout=5.0)
    S = ClusterState.bottom()
    for w in ("w0", "w1", "w2", "w3"):
        S = S.join(S.join_delta(w, w, 0.0))
    for t in (2.0, 4.0, 6.0):
        for w in ("w0", "w1", "w2"):  # w3 is slow
            S = S.join(S.beat_delta(w, t))
    q = m.quorum(S, now=6.0, fraction=0.5)
    assert q == {"w0", "w1", "w2"}  # progress without w3


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_metrics_duplicate_safe_and_exact():
    a, b = Metrics("r0"), Metrics("r1")
    d1 = a.observe("loss", 2.0)
    d2 = a.observe("loss", 4.0)
    d3 = b.observe("loss", 6.0)
    # deliver with duplication and reordering
    merged = MetricsState.bottom().join(d3).join(d2).join(d2).join(d1).join(d3)
    assert merged.count("loss") == 3
    assert merged.total("loss") == 12.0
    assert merged.mean("loss") == 4.0
    assert merged.minimum("loss") == 2.0
    assert merged.maximum("loss") == 6.0


def test_metrics_stale_report_subsumed():
    a = Metrics("r0")
    old = a.observe("tokens", 100.0, weight=1)
    new = a.observe("tokens", 100.0, weight=1)   # n=2 now
    merged = MetricsState.bottom().join(new).join(old)  # stale arrives late
    assert merged.count("tokens") == 2
    assert merged.total("tokens") == 200.0
