"""Tensor lattices: the δ-CRDT bridge to ML training state.

Checks: (i) the versioned chunk store is a join-semilattice and satisfies
the decomposition law for chunk writes; (ii) the sparse wire format
round-trips and realizes size(mᵟ(X)) ≪ size(X); (iii) the additive dot
store is duplicate-safe; (iv) the §7.2-compressed IntervalSum is EXACTLY
the dot store under causal (Algorithm-2-style) delivery."""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import pytest as _pytest
_pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core.tensor_lattice import (ChunkedTensor, DotSumStore,
                                       IntervalSum, TensorState, chunk_tensor,
                                       pack_delta, packed_size_bytes,
                                       unchunk, unpack_delta)

NAMES = ["w1", "w2"]
N_CHUNKS = 4
CHUNK = 8


def _random_states(seed, n_replicas=3, n_ops=10):
    rng = random.Random(seed)
    nprng = np.random.default_rng(seed)
    states = [TensorState.bottom() for _ in range(n_replicas)]
    # initialise all replicas with the same bottom-version tensors
    init = {}
    for nm in NAMES:
        ct = chunk_tensor(np.zeros(N_CHUNKS * CHUNK, np.float32), CHUNK)
        init[nm] = ct
    states = [TensorState.of(init) for _ in range(n_replicas)]
    for _ in range(n_ops):
        r = rng.randrange(n_replicas)
        if rng.random() < 0.7:
            nm = rng.choice(NAMES)
            k = rng.randint(1, N_CHUNKS)
            idx = nprng.choice(N_CHUNKS, size=k, replace=False)
            vals = nprng.normal(size=(k, CHUNK)).astype(np.float32)
            d = states[r].write_delta(r, nm, vals, chunk_idx=idx)
            states[r] = states[r].join(d)
        else:
            src = rng.randrange(n_replicas)
            states[r] = states[r].join(states[src])
    return states


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_tensorstate_lattice_laws(seed):
    a, b, c = _random_states(seed)
    assert a.join(a) == a
    assert a.join(b) == b.join(a)
    assert a.join(b).join(c) == a.join(b.join(c))
    assert a.join(TensorState.bottom()) == a
    assert a.leq(a.join(b)) and b.leq(a.join(b))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_tensorstate_write_decomposition(seed):
    rng = np.random.default_rng(seed)
    X = _random_states(seed)[0]
    idx = rng.choice(N_CHUNKS, size=2, replace=False)
    vals = rng.normal(size=(2, CHUNK)).astype(np.float32)
    full = X.write_full(1, "w1", vals, chunk_idx=idx)
    delta = X.write_delta(1, "w1", vals, chunk_idx=idx)
    assert full == X.join(delta)          # m(X) = X ⊔ mᵟ(X)
    # the delta really applied
    got = np.asarray(unchunk(full.as_dict()["w1"], (N_CHUNKS, CHUNK)))
    assert np.allclose(got[idx], vals)


def test_pack_delta_is_sparse_and_roundtrips():
    X = _random_states(0)[0]
    idx = np.array([2])
    vals = np.ones((1, CHUNK), np.float32)
    delta = X.write_delta(0, "w1", vals, chunk_idx=idx)
    wire = pack_delta(delta)
    assert list(wire["tensors"].keys()) == ["w1"]
    assert wire["tensors"]["w1"][0].tolist() == [2]  # only the touched chunk
    rt = unpack_delta(wire)
    assert X.join(rt) == X.join(delta)
    # sparse payload ≪ dense full state
    full_state_bytes = sum(np.asarray(ct.values).nbytes
                           for _, ct in X.chunks)
    assert packed_size_bytes(wire) < full_state_bytes / 4


def test_pack_delta_respects_known_versions():
    X = _random_states(3)[0]
    d1 = X.write_delta(0, "w1", np.ones((1, CHUNK), np.float32),
                       chunk_idx=np.array([1]))
    X2 = X.join(d1)
    known = {nm: np.asarray(ct.versions) for nm, ct in X2.chunks}
    d2 = X2.write_delta(0, "w2", np.ones((1, CHUNK), np.float32),
                        chunk_idx=np.array([3]))
    # shipping (d1 ⊔ d2) to a receiver that already has X2: only d2 survives
    wire = pack_delta(d1.join(d2), known_versions=known)
    assert set(wire["tensors"]) == {"w2"}


def test_version_tie_break_is_deterministic():
    """Concurrent writes to the same chunk: higher (lamport, rank) wins on
    BOTH replicas — convergence despite conflict."""
    base = _random_states(1)[0]
    da = base.write_delta(0, "w1", np.full((1, CHUNK), 7, np.float32),
                          chunk_idx=np.array([0]))
    db = base.write_delta(1, "w1", np.full((1, CHUNK), 9, np.float32),
                          chunk_idx=np.array([0]))
    ab = base.join(da).join(db)
    ba = base.join(db).join(da)
    assert ab == ba
    got = np.asarray(unchunk(ab.as_dict()["w1"], (N_CHUNKS, CHUNK)))[0]
    assert np.allclose(got, 9)  # same lamport, rank 1 > rank 0


# ---------------------------------------------------------------------------
# Additive dot store + compression
# ---------------------------------------------------------------------------

def _upd(seed):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(3,)).astype(np.float32))}


def test_dotsum_duplicate_and_reorder_safe():
    S = DotSumStore.bottom()
    d1 = S.contribute_delta("p0", _upd(1))
    S1 = S.join(d1)
    d2 = S1.contribute_delta("p0", _upd(2))
    # deliver in both orders, with duplicates
    X = DotSumStore.bottom().join(d2).join(d1).join(d2).join(d1)
    Y = DotSumStore.bottom().join(d1).join(d2)
    assert X == Y
    want = _upd(1)["a"] + _upd(2)["a"]
    assert np.allclose(np.asarray(X.total()["a"]), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dotsum_lattice_laws(seed):
    rng = random.Random(seed)
    stores = [DotSumStore.bottom() for _ in range(3)]
    for k in range(10):
        r = rng.randrange(3)
        if rng.random() < 0.7:
            d = stores[r].contribute_delta(f"p{r}", _upd(seed + k))
            stores[r] = stores[r].join(d)
        else:
            stores[r] = stores[r].join(stores[rng.randrange(3)])
    a, b, c = stores
    assert a.join(b) == b.join(a)
    assert a.join(b).join(c) == a.join(b.join(c))
    assert a.join(a) == a


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_interval_sum_matches_dot_store_under_causal_delivery(seed):
    """§7.2 compression exactness: deliver per-producer delta-intervals with
    duplications and rejected gaps; the (vv, sum) encoding must equal the
    explicit dot store."""
    rng = random.Random(seed)
    ref = DotSumStore.bottom()
    agg = IntervalSum()
    producers = ["p0", "p1"]
    produced = {p: [] for p in producers}
    for k in range(20):
        p = rng.choice(producers)
        upd = _upd(seed * 31 + k)
        produced[p].append(upd)
        ref = ref.join(ref.contribute_delta(p, upd))
        # attempt deliveries in random order, incl. duplicates and gaps
        for _ in range(rng.randint(1, 3)):
            q = rng.choice(producers)
            if not produced[q]:
                continue
            a = rng.randint(1, len(produced[q]) + 1)
            b = rng.randint(a, len(produced[q]) + 1)
            applied = agg.apply_interval(q, a, produced[q][a - 1:b - 1])
            # gaps must be rejected (causal delta-merging condition)
            if a - 1 > agg.prefix.get(q, 0):
                assert not applied or a - 1 <= agg.prefix.get(q, 0)
    # final anti-entropy: deliver everything in order
    for p in producers:
        agg.apply_interval(p, 1, produced[p])
    assert agg.matches(ref, atol=1e-4)
