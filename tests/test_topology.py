"""Topology layer tests: link classes, zone-spreading rendezvous
ownership, hierarchical gossip, relay election + failover.

The load-bearing properties:

* link classification is a pure function of the two endpoint zones
  (same zone → intra, same region → inter, else wan);
* zone-spread ownership puts every key's write set across ≥ 2 failure
  domains whenever ≥ 2 zones exist (and ``replication ≥ 2``), degrades
  to *exactly* flat rendezvous ownership on a single zone, and keeps
  the minimal-reshuffle property under worker join/leave;
* hierarchical gossip converges (Def. 6: relayed digest routing is
  join-equivalent), routes cross-zone traffic through elected relays
  only, and ships strictly fewer cross-zone bytes than the flat mesh on
  an identical seeded workload;
* killing a zone's relay mid-run elects a new one (HRW over the live
  set — no protocol, no extra state) and the zone still converges;
* a zone partition heals: writes made on both sides while a zone was
  cut off converge after the window closes.
"""

import random

import pytest

from repro.core import (Compose, GCounter, MVRegister, NetConfig,
                        Simulator, StoreReplica, converged,
                        hierarchical_policy, make_policy,
                        run_to_convergence)
from repro.core.hiergossip import HierarchicalGossip
from repro.sync import KeyOwnership, ShardByKey, owners_for_key
from repro.topology import (DEFAULT_PROFILES, INTER, INTRA, WAN,
                            LinkProfile, Topology, hrw_score, link_class,
                            parse_zone_map, relay_for, zone_region)


# ---------------------------------------------------------------------------
# Link classes + construction helpers
# ---------------------------------------------------------------------------

def test_zone_region_and_link_class():
    assert zone_region("eu/a") == "eu"
    assert zone_region("z0") == "z0"            # bare zone = own region
    assert link_class("eu/a", "eu/a") == INTRA
    assert link_class("eu/a", "eu/b") == INTER
    assert link_class("eu/a", "us/a") == WAN
    assert link_class("z0", "z1") == WAN        # bare zones are WAN apart


def test_topology_zone_lookup_and_links():
    topo = Topology({"a": "eu/x", "b": "eu/y", "c": "us/x"})
    assert topo.zone("a") == "eu/x"
    assert topo.zone("stranger") == topo.default_zone
    assert topo.link_class("a", "b") == INTER
    assert topo.link_class("a", "c") == WAN
    assert topo.link_class("a", "a") == INTRA
    assert topo.byte_cost("a", "c") == 1.0      # no profiles attached
    zoned = Topology({"a": "eu/x", "c": "us/x"},
                     profiles=DEFAULT_PROFILES)
    assert zoned.byte_cost("a", "c") == DEFAULT_PROFILES[WAN].byte_cost
    with pytest.raises(ValueError, match="unknown link class"):
        Topology({}, profiles={"submarine": LinkProfile()})


def test_topology_zoned_round_robin_and_flat():
    ids = [f"w{k}" for k in range(7)]
    topo = Topology.zoned(ids, 3)
    by_zone = topo.by_zone(ids)
    assert set(by_zone) == {"z0", "z1", "z2"}
    assert sum(len(ws) for ws in by_zone.values()) == 7
    # deterministic in worker order, balanced within 1
    sizes = sorted(len(ws) for ws in by_zone.values())
    assert sizes[-1] - sizes[0] <= 1
    flat = Topology.flat(ids)
    assert flat.zone_names(ids) == (flat.default_zone,)
    with pytest.raises(ValueError, match="at least one zone"):
        Topology.zoned(ids, 0)


def test_parse_zone_map():
    assert parse_zone_map("gw0=eu/a, gw1=eu/b") == {"gw0": "eu/a",
                                                    "gw1": "eu/b"}
    assert parse_zone_map({"a": "z"}) == {"a": "z"}
    assert parse_zone_map(None) == {}
    with pytest.raises(ValueError, match="ID=ZONE"):
        parse_zone_map("gw0")


def test_relay_election_is_deterministic_and_zone_local():
    ids = [f"w{k}" for k in range(9)]
    topo = Topology.zoned(ids, 3)
    for z in topo.zone_names(ids):
        r = topo.relay(z, ids)
        assert r in topo.members(z, ids)
        assert topo.relay(z, list(reversed(ids))) == r   # order-blind
        # HRW: the relay is the zone's max scorer on the zone's key
        assert hrw_score(r, f"relay:{z}") == max(
            hrw_score(m, f"relay:{z}") for m in topo.members(z, ids))
    assert topo.relay("z0", []) is None
    assert relay_for("z9", ids, topo.zone) is None       # empty zone


def test_relay_failover_is_removal_from_live_set():
    ids = [f"w{k}" for k in range(9)]
    topo = Topology.zoned(ids, 3)
    old = topo.relay("z0", ids)
    live = [w for w in ids if w != old]
    new = topo.relay("z0", live)
    assert new is not None and new != old
    assert topo.zone(new) == "z0"


# ---------------------------------------------------------------------------
# Zone-spreading rendezvous ownership (seeded property loops)
# ---------------------------------------------------------------------------

def _keys(rng, n=40):
    return [f"key{rng.randrange(10_000)}" for _ in range(n)]


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n_zones", [2, 3, 4])
def test_write_set_crosses_two_zones_whenever_possible(seed, n_zones):
    rng = random.Random(seed)
    n = rng.randrange(n_zones, 13)
    ids = [f"w{k}" for k in range(n)]
    topo = Topology.zoned(ids, n_zones)
    own = KeyOwnership(ids, replication=min(3, n), topology=topo)
    for key in _keys(rng):
        owners = own.owners(key)
        assert len(owners) == min(3, n)
        assert len(set(owners)) == len(owners)
        zones = {topo.zone(w) for w in owners}
        if own.replication >= 2 and len(topo.zone_names(ids)) >= 2:
            assert len(zones) >= 2, (key, owners, zones)


@pytest.mark.parametrize("seed", [3, 4])
def test_single_zone_ownership_is_exactly_flat(seed):
    rng = random.Random(seed)
    ids = [f"w{k}" for k in range(rng.randrange(2, 9))]
    flat = KeyOwnership(ids, replication=2, read_replication=4)
    one = KeyOwnership(ids, replication=2, read_replication=4,
                       topology=Topology.flat(ids))
    none = KeyOwnership(ids, replication=2, read_replication=4,
                        topology=None)
    for key in _keys(rng):
        assert one.owners(key) == flat.owners(key)
        assert one.readers(key) == flat.readers(key)
        assert none.owners(key) == flat.owners(key)
        assert flat.owners(key) == owners_for_key(key, ids, 2)


@pytest.mark.parametrize("seed", [5, 6, 7])
def test_join_leave_reshuffle_is_minimal(seed):
    """A key's write set changes on a membership change only when the
    changed worker itself sits in the (new/old) rendezvous prefix or
    write set — rendezvous minimal disruption, preserved by the
    zone-spread swap (the swap target is rank-maximal among other-zone
    workers, so only the joiner/leaver can displace it)."""
    rng = random.Random(seed)
    n = rng.randrange(5, 12)
    ids = [f"w{k}" for k in range(n)]
    topo = Topology.zoned(ids, 3)
    r = 3
    own = KeyOwnership(ids, replication=r, topology=topo)
    keys = _keys(rng, 60)
    before = {k: own.owners_among(k, ids) for k in keys}

    joiner = "w_new"
    topo.zones[joiner] = f"z{rng.randrange(3)}"
    with_j = sorted([*ids, joiner])
    moved = 0
    for k in keys:
        after = own.owners_among(k, with_j)
        if after != before[k]:
            moved += 1
            prefix = owners_for_key(k, with_j, r)
            assert joiner in set(prefix) | set(after), (
                k, before[k], after)
    assert moved < len(keys)      # a join never reshuffles everything

    leaver = rng.choice(ids)
    without_l = [w for w in ids if w != leaver]
    for k in keys:
        after = own.owners_among(k, without_l)
        if after != before[k]:
            prefix = owners_for_key(k, ids, r)
            assert leaver in set(prefix) | set(before[k]), (
                k, before[k], after)


def test_read_extension_prefers_zone_coverage():
    ids = [f"w{k}" for k in range(9)]
    topo = Topology.zoned(ids, 3)
    own = KeyOwnership(ids, replication=2, read_replication=3,
                       topology=topo)
    rng = random.Random(11)
    for key in _keys(rng):
        readers = own.readers(key)[:3]
        assert len({topo.zone(w) for w in readers}) == 3, (key, readers)


def test_relays_buffer_and_route_zone_mates_reads():
    ids = [f"w{k}" for k in range(6)]
    topo = Topology.zoned(ids, 3)
    own = KeyOwnership(ids, replication=2, topology=topo)
    relays = own.relays()
    assert set(relays) == {"z0", "z1", "z2"}
    rng = random.Random(13)
    for key in _keys(rng, 20):
        for z, relay in relays.items():
            zone_reads = any(own.reads(m, key)
                             for m in topo.members(z, ids))
            assert own.routes_pull(relay, key) == (
                own.reads(relay, key) or zone_reads)
            assert own.buffers(relay, key) == (
                own.replicates(relay, key) or zone_reads)
        for w in ids:
            if w not in relays.values():
                assert own.routes_pull(w, key) == own.reads(w, key)
                assert own.buffers(w, key) == own.replicates(w, key)


# ---------------------------------------------------------------------------
# Simulator: per-class link conditions + zone partitions
# ---------------------------------------------------------------------------

def test_simulator_classes_bytes_and_bills_wan():
    ids = ["a", "b", "c"]
    topo = Topology.zoned(ids, 3, profiles=DEFAULT_PROFILES)
    sim = Simulator(NetConfig(seed=0), topology=topo)
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=make_policy("bp+rr"), rng=random.Random(1))) for i in ids]
    nodes[0].update("k", GCounter, "inc_delta", "a")
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    # one worker per zone: every link is cross-zone (bare zones → wan)
    assert set(sim.stats.bytes_by_class) == {WAN}
    assert sim.stats.cross_zone_bytes() == sim.stats.bytes_sent
    # the cost model bills wan bytes at the wan multiplier
    assert sim.stats.link_cost == pytest.approx(
        sim.stats.bytes_sent * DEFAULT_PROFILES[WAN].byte_cost)


def test_zone_partition_requires_topology_and_nonempty_sides():
    sim = Simulator(NetConfig(seed=0))
    with pytest.raises(ValueError, match="topology"):
        sim.add_zone_partition(0, 1, "z0")
    topo = Topology.zoned(["a", "b"], 2)
    sim2 = Simulator(NetConfig(seed=0), topology=topo)
    sim2.add_node(StoreReplica("a", ["b"], causal=True))
    sim2.add_node(StoreReplica("b", ["a"], causal=True))
    with pytest.raises(ValueError, match="empty side"):
        sim2.add_zone_partition(0, 1, "z9")
    sim2.add_zone_partition(0, 1, "z0")      # both sides populated: ok
    assert sim2.partitions


# ---------------------------------------------------------------------------
# Hierarchical gossip end-to-end (sim)
# ---------------------------------------------------------------------------

def _zoned_cluster(n=9, n_zones=3, seed=1, policy=None, topo=None,
                   profiles=None):
    ids = [f"w{k}" for k in range(n)]
    topo = topo or Topology.zoned(ids, n_zones, profiles=profiles)
    sim = Simulator(NetConfig(seed=seed), topology=topo)
    make = policy or (lambda: hierarchical_policy(topo, inter_every=4))
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True, policy=make(),
        rng=random.Random(seed + 1))) for i in ids]
    return topo, sim, ids, nodes


def _workload(sim, nodes, rng, n_writes=40, n_keys=8):
    """Writes spread across live gossip rounds (schedules anti-entropy
    up front, compatible with a later ``run_to_convergence`` call)."""
    for n in nodes:
        sim.every(1.0, n.on_periodic)
        sim.every(7.0, n.gc_deltas)
    sim._ae_scheduled = {n.id for n in nodes}
    for t in range(n_writes):
        n = rng.choice(nodes)
        n.update(f"k{t % n_keys}", GCounter, "inc_delta", n.id)
        sim.run_for(1.0)
    return n_writes


def test_hierarchical_gossip_converges_and_beats_flat_on_wan_bytes():
    results = {}
    for label, hier in (("flat", False), ("hier", True)):
        topo, sim, ids, nodes = _zoned_cluster(
            seed=2, policy=(None if hier
                            else (lambda: make_policy("bp+rr"))))
        writes = _workload(sim, nodes, random.Random(3))
        run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
        assert converged(nodes)
        total = sum(nodes[0].get(f"k{j}").value() for j in range(8))
        assert total == writes
        results[label] = sim.stats
    assert results["hier"].cross_zone_bytes() \
        < results["flat"].cross_zone_bytes()


def test_hierarchical_gossip_only_relays_cross_zones():
    topo, sim, ids, nodes = _zoned_cluster(seed=5)
    relays = {topo.relay(z, ids) for z in topo.zone_names(ids)}
    _workload(sim, nodes, random.Random(5))
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    # replay target selection: only relays ever address other zones
    for n in nodes:
        targets = n.policy.targets(n, list(n.neighbors))
        cross = [t for t in targets if topo.zone(t) != topo.zone(n.id)]
        if n.id in relays:
            assert cross and all(t in relays for t in cross)
        else:
            assert not cross


def test_hierarchical_gossip_gc_with_single_member_zone():
    """A single-member zone has no intra-zone push peers, so no acks
    ever arrive — the ack_peers hook must let the buffer clear instead
    of pinning it forever (digest-sync is the repair path)."""
    topo, sim, ids, nodes = _zoned_cluster(n=3, n_zones=3, seed=7)
    _workload(sim, nodes, random.Random(7), n_writes=20)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    for n in nodes:
        n.gc_deltas()
        assert not n.entries, (n.id, len(n.entries))


def test_zone_partition_heals_and_converges():
    topo, sim, ids, nodes = _zoned_cluster(seed=9,
                                           profiles=DEFAULT_PROFILES)
    rng = random.Random(9)
    _workload(sim, nodes, rng, n_writes=15)
    # cut z1 off for a window; write on BOTH sides meanwhile
    t0 = sim.time
    sim.add_zone_partition(t0, t0 + 30.0, "z1")
    inside = [n for n in nodes if topo.zone(n.id) == "z1"]
    outside = [n for n in nodes if topo.zone(n.id) != "z1"]
    for t in range(10):
        a = inside[t % len(inside)]
        a.update("cut", GCounter, "inc_delta", a.id)
        b = outside[t % len(outside)]
        b.update("cut", GCounter, "inc_delta", b.id)
        sim.run_for(2.0)
    sim.run_until(t0 + 30.0)                 # heal
    deadline = sim.time + 10_000
    while sim.time < deadline and not converged(nodes):
        sim.run_for(5.0)
    assert converged(nodes)
    assert nodes[0].get("cut").value() == 20   # no write lost on either side


def test_relay_failover_mid_run_zone_still_converges():
    """Kill z0's relay mid-run: the survivors prune it from their
    neighbor lists (elastic membership), HRW over the live set elects a
    new z0 relay, and cross-zone digest-sync keeps the zone converging."""
    topo, sim, ids, nodes = _zoned_cluster(seed=11)
    rng = random.Random(11)
    _workload(sim, nodes, rng, n_writes=15)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)

    old = topo.relay("z0", ids)
    live_ids = [i for i in ids if i != old]
    new = topo.relay("z0", live_ids)
    assert new != old and topo.zone(new) == "z0"

    by_id = {n.id: n for n in nodes}
    by_id[old].alive = False                 # crash, never recovers
    survivors = [n for n in nodes if n.id != old]
    for n in survivors:
        n.neighbors.remove(old)              # membership eviction
        n.prune_departed()
    # the new relay is what the *policy* now elects on every survivor
    for n in survivors:
        hier = n.policy.policies[-1]
        assert isinstance(hier, HierarchicalGossip)
        if topo.zone(n.id) != "z0":
            continue
        cross = hier.relay_targets(n, list(n.neighbors))
        if n.id == new:
            assert cross and all(topo.zone(t) != "z0" for t in cross)
        else:
            assert cross == []
    # writes born in z0 after the failover still reach every zone
    z0_survivors = [n for n in survivors if topo.zone(n.id) == "z0"]
    for t in range(10):
        n = z0_survivors[t % len(z0_survivors)]
        n.update("post", GCounter, "inc_delta", n.id)
        sim.run_for(0.5)
    run_to_convergence(sim, survivors, interval=1.0, max_time=60_000)
    assert converged(survivors)
    assert survivors[0].get("post").value() == 10


def test_hierarchical_composes_with_shard_by_key():
    """HierarchicalGossip × ShardByKey: zone relays aggregate their
    zone's read interest across the boundary, so every owner converges
    per key even when the owners span zones and no raw fanout crosses."""
    ids = [f"w{k}" for k in range(6)]
    topo = Topology.zoned(ids, 3)
    own = KeyOwnership(ids, replication=3, topology=topo)
    sim = Simulator(NetConfig(seed=13), topology=topo)
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=Compose(make_policy("bp+rr"), ShardByKey(own),
                       HierarchicalGossip(topo)),
        rng=random.Random(14), ownership=own)) for i in ids]
    by_id = {n.id: n for n in nodes}
    rng = random.Random(15)
    keys = [f"k{j}" for j in range(6)]
    for t in range(24):
        key = keys[t % 6]
        # clients route writes by ownership (owners_for_key), as the
        # flat-mesh cross-zone push path is intentionally cut
        n = by_id[rng.choice(own.owners(key))]
        n.update(key, MVRegister, "write_delta", n.id, f"v{t}")
        if rng.random() < 0.5:
            sim.run_for(0.4)

    def settled():
        for k in keys:
            vals = [by_id[w].get(k, MVRegister).read()
                    for w in own.owners(k)]
            if any(v != vals[0] for v in vals[1:]):
                return False
        return True

    for n in nodes:
        sim.every(1.0, n.on_periodic)
        sim.every(7.0, n.gc_deltas)
    deadline = sim.time + 10_000
    while sim.time < deadline and not settled():
        sim.run_for(5.0)
    assert settled()


def test_hierarchical_policy_validation():
    topo = Topology.zoned(["a", "b"], 2)
    with pytest.raises(ValueError, match="inter_every"):
        HierarchicalGossip(topo, inter_every=0)
    pol = hierarchical_policy(topo, base=None)
    assert isinstance(pol, HierarchicalGossip)
    assert hierarchical_policy(topo).name == "bp+rr+hier"
    assert HierarchicalGossip(topo, inter_every=3).name == "hier:3"
