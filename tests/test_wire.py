"""Binary δ-wire subsystem tests: frames, codec round-trips, sparse
ingest, engine integration, and rebalance handoff.

The load-bearing properties:

* ``decode(encode(x))`` is lattice-equal to ``x`` for any store mixing
  tensor and non-tensor values (property-tested over random stores with
  ragged chunk counts, multiple dtypes, random sparsity, empty deltas);
* joining a decoded (sparse, zero-copy) delta into resident state gives
  exactly the state joining the original delta would;
* a corrupted frame is rejected by checksum/structure validation before
  any payload byte is interpreted;
* replicas gossiping frames converge to the same states as replicas
  gossiping Python objects, under every policy combination tested;
* rebalance handoff delivers moved keys in strictly fewer rounds than
  organic anti-entropy, with identical converged states.
"""

import random

import numpy as np
import pytest

from repro.core import (AWORSet, CausalNode, Compose, GCounter,
                        LatticeStore, MVRegister, NetConfig, Simulator,
                        StoreReplica, converged, make_policy,
                        run_to_convergence, structural_size)
from repro.core.tensor_lattice import (ChunkedTensor, SparseChunks,
                                       TensorState, chunk_tensor,
                                       pack_delta, sparse_chunks,
                                       unpack_delta)
from repro.sync import KeyOwnership, RebalanceHandoff, ShardByKey
from repro.wire import (FrameBytes, FrameError, WireCodec, decode_digest,
                        decode_frame, decode_store, decode_value,
                        encode_digest, encode_frame, encode_store,
                        encode_value, peek_kind)


# ---------------------------------------------------------------------------
# Frames
# ---------------------------------------------------------------------------

def test_frame_roundtrip_and_kind_tag():
    payload = b"some payload bytes"
    for kind in ("delta", "state", "ack", "handoff", "membership",
                 "digest", "topk"):
        fr = encode_frame(kind, payload)
        assert isinstance(fr, FrameBytes) and fr.kind == kind
        assert peek_kind(fr) == kind
        got_kind, got = decode_frame(fr)
        assert got_kind == kind and bytes(got) == payload


def test_frame_rejects_unknown_kind():
    with pytest.raises(FrameError):
        encode_frame("nonsense", b"")


def test_frame_corruption_rejected():
    fr = encode_frame("delta", b"x" * 100)
    # flip one payload byte → CRC failure
    corrupt = bytearray(fr)
    corrupt[40] ^= 0x5A
    with pytest.raises(FrameError, match="checksum"):
        decode_frame(bytes(corrupt))
    # bad magic
    bad_magic = b"XX" + fr[2:]
    with pytest.raises(FrameError, match="magic"):
        decode_frame(bad_magic)
    # newer format version → reject, don't guess
    bumped = bytearray(fr)
    bumped[2] += 1
    with pytest.raises(FrameError, match="version"):
        decode_frame(bytes(bumped))
    # truncation (header or payload)
    with pytest.raises(FrameError):
        decode_frame(fr[:6])
    with pytest.raises(FrameError, match="length"):
        decode_frame(fr[:-3])


def test_frame_bytes_measured_by_simulator():
    fr = encode_frame("delta", b"y" * 37)
    assert structural_size(fr) == len(fr)


# ---------------------------------------------------------------------------
# Store codec round-trips
# ---------------------------------------------------------------------------

def _mixed_store() -> LatticeStore:
    ts = TensorState.of(
        {"w": chunk_tensor(np.arange(48, dtype=np.float32), 8, version=3),
         "b": chunk_tensor(np.ones(6, np.float32), 4, version=9)},
        lamport=4)
    return LatticeStore.of({
        "tensors": ts,
        "counter": GCounter.bottom().inc_delta("r0"),
        "set": AWORSet.bottom().add_delta("r1", "elem"),
        "reg": MVRegister.bottom().write_delta("r2", "value"),
        "empty": TensorState.bottom(),
    })


def test_store_codec_roundtrip_mixed():
    store = _mixed_store()
    dec = decode_store(encode_store(store))
    assert dec == store
    assert dec.leq(store) and store.leq(dec)


def test_store_codec_roundtrip_empty():
    assert decode_store(encode_store(LatticeStore.bottom())) \
        == LatticeStore.bottom()


def test_decoded_tensors_are_sparse_views():
    store = _mixed_store()
    dec = decode_store(encode_store(store))
    ts = dec.get("tensors")
    for _, ct in ts.chunks:
        assert isinstance(ct, SparseChunks)


def test_decoded_join_equals_original_join():
    base = _mixed_store()
    ts = base.get("tensors")
    delta_ts = ts.write_delta(1, "w", np.full((2, 8), 5, np.float32),
                              chunk_idx=np.array([0, 3]))
    delta = LatticeStore.of({
        "tensors": delta_ts,
        "counter": GCounter.bottom().inc_delta("r9"),
    })
    dec = decode_store(encode_store(delta))
    assert base.join(dec) == base.join(delta)


def test_value_codec_bare_tensorstate_and_opaque():
    ts = TensorState.of(
        {"w": chunk_tensor(np.arange(16, dtype=np.float32), 4, version=2)})
    assert decode_value(encode_value(ts)) == ts
    s = AWORSet.bottom().add_delta("r0", "x")
    assert decode_value(encode_value(s)) == s


def test_digest_roundtrip():
    from repro.core import opaque_hash, store_digest

    store = _mixed_store()
    dig = decode_digest(encode_digest(store))
    assert dig == store_digest(store)
    ts = store.get("tensors")
    assert set(dig.tensors) == {("tensors", "w"), ("tensors", "b")}
    for (key, name), vers in dig.tensors.items():
        assert np.array_equal(
            vers, np.asarray(ts.as_dict()[name].versions))
    # non-tensor keys: causal dot-store types carry per-dot causal
    # summaries (vv + cloud + store dot column), the rest content hashes
    assert set(dig.opaque) == {"counter"}
    assert set(dig.causal) == {"set", "reg"}
    assert dig.opaque["counter"] == opaque_hash(store.get("counter"))


# ---------------------------------------------------------------------------
# Sparse ingest path (unpack_delta and SparseChunks semantics)
# ---------------------------------------------------------------------------

def _base_state(seed=0, n_chunks=6, chunk=8) -> TensorState:
    rng = np.random.default_rng(seed)
    return TensorState.of({
        "w1": chunk_tensor(
            rng.normal(size=(n_chunks * chunk,)).astype(np.float32),
            chunk, version=1),
        "w2": chunk_tensor(
            rng.normal(size=(n_chunks * chunk,)).astype(np.float32),
            chunk, version=1)})


def test_unpack_sparse_joins_like_dense():
    X = _base_state()
    delta = X.write_delta(0, "w1", np.ones((2, 8), np.float32),
                          chunk_idx=np.array([1, 4]))
    wire = pack_delta(delta)
    sp = unpack_delta(wire)
    dn = unpack_delta(wire, sparse=False)
    assert all(ct.is_sparse for _, ct in sp.chunks)
    assert sp == dn == delta
    assert X.join(sp) == X.join(dn) == X.join(delta)


def test_sparse_sparse_join_matches_dense_oracle():
    X = _base_state(1)
    d1 = X.write_delta(0, "w1", np.ones((2, 8), np.float32),
                       chunk_idx=np.array([0, 2]))
    d2 = X.join(d1).write_delta(1, "w1", np.full((2, 8), 2, np.float32),
                                chunk_idx=np.array([2, 5]))
    sp = unpack_delta(pack_delta(d1)).join(unpack_delta(pack_delta(d2)))
    dn = unpack_delta(pack_delta(d1), sparse=False).join(
        unpack_delta(pack_delta(d2), sparse=False))
    # the sparse group stays sparse (O(rows) union, no densify)
    assert all(ct.is_sparse for _, ct in sp.chunks)
    assert sp == dn
    assert X.join(sp) == X.join(dn)


def test_sparse_leq_and_eq_cross_density():
    X = _base_state(2)
    delta = X.write_delta(0, "w2", np.ones((1, 8), np.float32),
                          chunk_idx=np.array([3]))
    sp = unpack_delta(pack_delta(delta))
    assert sp.leq(X.join(delta))
    assert not sp.leq(X)            # fresh version not covered
    assert sp == delta and delta == sp
    assert not (sp == X)
    # empty sparse delta ≡ bottom
    empty = TensorState.of({"w2": sparse_chunks(
        6, np.array([], np.int32), np.zeros((0, 8), np.float32),
        np.array([], np.int32))})
    assert empty == TensorState.bottom()
    assert empty.leq(X)


def test_store_batched_join_falls_back_on_sparse():
    """A store holding sparse values must not take the stacked fast path
    (rows are not a dense column block) but still join correctly."""
    a = LatticeStore.of({"k": _base_state(3)})
    delta = _base_state(3).write_delta(
        0, "w1", np.ones((1, 8), np.float32), chunk_idx=np.array([2]))
    sp_store = decode_store(encode_store(LatticeStore.of({"k": delta})))
    assert a.join(sp_store, batched=True) \
        == a.join(LatticeStore.of({"k": delta}), batched=False)


def test_sparse_chunks_dedups_by_version():
    """Ad-hoc duplicate chunk positions keep the higher-versioned row —
    the same LWW rule the join applies."""
    sp = sparse_chunks(4, np.array([2, 2]),
                       np.stack([np.full(8, 7.0, np.float32),
                                 np.full(8, 3.0, np.float32)]),
                       np.array([5, 3]))
    assert sp.idx.tolist() == [2]
    assert sp.vers.tolist() == [5]
    assert np.all(sp.vals == 7.0)


def test_sparse_resident_state_supports_dense_consumers():
    """A wire-decoded value can become durable resident state wholesale
    (a key the replica never writes locally); dense-only consumers —
    unchunk, checkpointing — must keep working on it."""
    from repro.core.tensor_lattice import unchunk

    ts = TensorState.of(
        {"w": chunk_tensor(np.arange(24, dtype=np.float32), 8, version=2)})
    dec = decode_store(encode_store(LatticeStore.of({"k": ts})))
    sp = dec.get("k").as_dict()["w"]
    assert sp.is_sparse
    got = unchunk(sp, (24,))
    assert np.array_equal(np.asarray(got), np.arange(24, dtype=np.float32))
    assert np.array_equal(np.asarray(sp.versions),
                          np.asarray(ts.as_dict()["w"].versions))


def test_topk_frame_roundtrip():
    import jax.numpy as jnp
    from repro.sync import TopKCompressor, topk_frame, topk_unframe

    comp = TopKCompressor(rate=0.25)
    upd = {"a": jnp.arange(32, dtype=jnp.float32),
           "b": {"c": jnp.ones((4, 8), jnp.float32)}}
    sp = comp.compress(upd)
    rt = topk_unframe(topk_frame(sp))
    dec_a, dec_b = TopKCompressor.decompress(sp), \
        TopKCompressor.decompress(rt)
    for x, y in zip([dec_a["a"], dec_a["b"]["c"]],
                    [dec_b["a"], dec_b["b"]["c"]]):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Engine integration: replicas moving frames
# ---------------------------------------------------------------------------

def _drive_orset(wire, seed=11, spec="bp+rr"):
    sim = Simulator(NetConfig(loss=0.2, dup=0.1, seed=seed))
    ids = [f"n{k}" for k in range(3)]
    nodes = [sim.add_node(CausalNode(
        i, AWORSet.bottom(), [j for j in ids if j != i],
        rng=random.Random(seed + 1), policy=make_policy(spec),
        ghost_check=True, wire=wire)) for i in ids]
    rng = random.Random(seed + 2)
    for k in range(25):
        n = rng.choice(nodes)
        n.operation(lambda X, i=n.id, k=k: X.add_delta(i, f"e{k % 9}"))
        sim.run_for(0.4)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    assert not [f for n in nodes for f in n.ghost_failures]
    return nodes[0].X, sim.stats


@pytest.mark.parametrize("spec", ["all", "bp+rr"])
def test_wire_replicas_match_object_replicas(spec):
    x_wire, stats_wire = _drive_orset(WireCodec(), spec=spec)
    x_obj, _ = _drive_orset(None, spec=spec)
    assert x_wire == x_obj
    # traffic was frames, and byte accounting measured their lengths
    assert stats_wire.bytes_by_kind.get("delta", 0) > 0
    assert stats_wire.bytes_by_kind.get("ack", 0) > 0


def test_wire_keyed_tensor_store_converges():
    wire = WireCodec()
    sim = Simulator(NetConfig(loss=0.15, seed=5))
    ids = [f"n{k}" for k in range(3)]
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        rng=random.Random(7), wire=wire)) for i in ids]
    rng = np.random.default_rng(0)
    for s in range(9):
        nodes[s % 3].update(f"obj{s}", TensorState, "write_delta", s % 3,
                            "w", rng.normal(size=(24,)).astype(np.float32),
                            None, 8)
        sim.run_for(0.5)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)


def test_wirecodec_message_roundtrips():
    wc = WireCodec()
    store = _mixed_store()
    # causal delta with ghost
    msg = wc.decode_msg(wc.encode_msg(("delta", store, 7, store)))
    assert msg[0] == "delta" and msg[2] == 7
    assert msg[1] == store and msg[3] == store
    # basic-mode delta
    kind, d = wc.decode_msg(wc.encode_msg(("delta", store)))
    assert kind == "delta" and d == store
    # ack / handoff
    assert wc.decode_msg(wc.encode_msg(("ack", 123))) == ("ack", 123)
    k, d = wc.decode_msg(wc.encode_msg(("handoff", store)))
    assert k == "handoff" and d == store
    # full-state framing is tagged as state traffic
    assert wc.encode_msg(("delta", store, 1, None),
                         full_state=True).kind == "state"


# ---------------------------------------------------------------------------
# Rebalance handoff
# ---------------------------------------------------------------------------

def _handoff_run(push: bool, seed=9):
    wire = WireCodec()
    live = ["w0", "w1", "w2"]
    ownership = KeyOwnership(lambda: list(live), replication=2)
    sim = Simulator(NetConfig(loss=0.0, seed=seed))
    ids = ["w0", "w1", "w2", "w3"]
    nodes = {i: sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=Compose(make_policy("bp+rr+every:6"), ShardByKey(ownership)),
        rng=random.Random(1), ownership=ownership, wire=wire))
        for i in ids}
    agents = [RebalanceHandoff(nodes[i], ownership) for i in ids]
    keys = [f"k{s:03d}" for s in range(24)]
    for s, key in enumerate(keys):
        nodes[live[s % 3]].update(key, GCounter, "inc_delta", live[s % 3])
        if s % 6 == 5:
            sim.run_for(1.0)
    for n in nodes.values():
        sim.every(1.0, n.on_periodic)
    sim.run_for(30.0)

    live.append("w3")
    moved = [k for k in keys if "w3" in ownership.owners(k)]
    assert moved, "rendezvous moved no keys — test vacuous"
    if push:
        assert sum(a.check() for a in agents) > 0
        assert all(a.check() == 0 for a in agents)   # idempotent per change
    t0 = sim.time
    tick = [0]

    def trickle():   # keeps the every:k fallback reachable
        tick[0] += 1
        nodes["w0"].update(f"fresh{tick[0]}", GCounter, "inc_delta", "w0")
    sim.every(1.0, trickle)

    def settled():
        return all(nodes["w3"].get(k) is not None
                   and nodes["w3"].get(k, GCounter).value() >= 1
                   for k in moved)

    while sim.time - t0 < 400:
        sim.run_for(1.0)
        if settled():
            break
    assert settled(), "moved keys never reached the new owner"
    states = {k: nodes["w3"].get(k, GCounter).value() for k in moved}
    return sim.time - t0, states


def test_handoff_converges_moved_keys_faster():
    t_push, s_push = _handoff_run(True)
    t_organic, s_organic = _handoff_run(False)
    assert s_push == s_organic          # identical converged states
    assert t_push < t_organic           # strictly fewer rounds


def test_handoff_noop_while_membership_stable():
    nodes = ["w0", "w1"]
    ownership = KeyOwnership(lambda: list(nodes), replication=1)
    sim = Simulator(NetConfig(seed=0))
    rep = sim.add_node(StoreReplica("w0", ["w1"], ownership=ownership))
    agent = RebalanceHandoff(rep, ownership)
    rep.update("k", GCounter, "inc_delta", "w0")
    assert agent.check() == 0
    assert agent.check() == 0
