"""Property tests (hypothesis) for the binary δ-wire codec:

* ``decode(encode(x)) == x`` over random stores mixing lattice types
  (tensor states with ragged chunk counts / random sparsity / several
  dtypes, counters, OR-Sets, empty deltas);
* joining the decoded (sparse, zero-copy) store into random resident
  state equals joining the original — the ingest-path faithfulness the
  engine relies on;
* random frame corruption never decodes silently: every flipped byte is
  either detected (FrameError) or harmless (decodes equal).
"""

import pytest
import pytest as _pytest
_pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

import numpy as np

from repro.core import AWORSet, GCounter, LatticeStore
from repro.core.tensor_lattice import (ChunkedTensor, TensorState,
                                       sparse_chunks)
from repro.wire import (FrameError, decode_frame, decode_store,
                        encode_frame, encode_store)

DTYPES = (np.float32, np.float16, np.int32)


@st.composite
def tensor_states(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    n_tensors = draw(st.integers(0, 3))
    chunks = {}
    for t in range(n_tensors):
        n_chunks = draw(st.integers(1, 7))          # ragged across tensors
        chunk = draw(st.sampled_from((4, 8, 16)))
        dtype = draw(st.sampled_from(DTYPES))
        if np.issubdtype(dtype, np.floating):
            vals = rng.normal(size=(n_chunks, chunk)).astype(dtype)
        else:
            vals = rng.integers(-50, 50,
                                size=(n_chunks, chunk)).astype(dtype)
        vers = rng.integers(0, 5, size=(n_chunks,)).astype(np.int32)
        vals[vers == 0] = 0                          # ⊥ invariant
        if draw(st.booleans()):                      # sparse-form value
            live = np.nonzero(vers > 0)[0]
            chunks[f"t{t}"] = sparse_chunks(
                n_chunks, live.astype(np.int32), vals[live], vers[live])
        else:
            chunks[f"t{t}"] = ChunkedTensor(vals, vers)
    return TensorState.of(chunks, lamport=draw(st.integers(0, 9)))


@st.composite
def stores(draw):
    out = {}
    for k in range(draw(st.integers(0, 5))):
        kind = draw(st.sampled_from(("tensor", "counter", "orset",
                                     "empty")))
        key = f"key{k}"
        if kind == "tensor":
            out[key] = draw(tensor_states())
        elif kind == "counter":
            c = GCounter.bottom()
            for r in range(draw(st.integers(1, 3))):
                c = c.join(c.inc_delta(f"r{r}"))
            out[key] = c
        elif kind == "orset":
            s = AWORSet.bottom()
            for e in range(draw(st.integers(1, 3))):
                s = s.join(s.add_delta("r0", f"e{e}"))
            out[key] = s
        else:
            out[key] = TensorState.bottom()
    return LatticeStore.of(out)


@settings(max_examples=40, deadline=None)
@given(store=stores())
def test_decode_encode_is_identity(store):
    dec = decode_store(encode_store(store))
    assert dec == store
    assert dec.leq(store) and store.leq(dec)


@settings(max_examples=25, deadline=None)
@given(resident=stores(), delta=stores())
def test_decoded_store_joins_identically(resident, delta):
    dec = decode_store(encode_store(delta))
    try:
        want = resident.join(delta)
    except Exception:
        # key-type mismatch between the two random stores (joining a
        # counter into a tensor key is a type error with or without the
        # codec) — not a wire property
        return
    assert resident.join(dec) == want


@settings(max_examples=40, deadline=None)
@given(store=stores(), flip=st.integers(0, 2**31 - 1),
       bit=st.integers(0, 7))
def test_corrupted_frames_never_decode_silently_wrong(store, flip, bit):
    frame = encode_frame("delta", encode_store(store))
    pos = flip % len(frame)
    corrupt = bytearray(frame)
    corrupt[pos] ^= 1 << bit
    if bytes(corrupt) == bytes(frame):
        return
    try:
        kind, payload = decode_frame(bytes(corrupt))
        dec = decode_store(payload)
    except Exception:
        return                      # rejected — the expected outcome
    # a flip that survives validation must not change the content (the
    # CRC covers header AND payload, so every single-bit flip should in
    # fact be rejected — this branch documents the safety property)
    assert kind == "delta" and dec == store
